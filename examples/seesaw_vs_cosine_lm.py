"""End-to-end driver (deliverable b): trains an OLMo-style model with
the paper's §4 protocol — AdamW(0.9, 0.95), 10% warmup, seq 1024,
Seesaw vs cosine at the critical batch size — through the production
trainer (per-phase compile cache, batch ramp, token-indexed LR).

Default: a ~4M-param reduction for a few hundred steps (CPU-friendly).
``--model 150m --steps 0`` runs the paper's full 150M Chinchilla recipe
(the exact preset; needs accelerators for sensible wall-clock).

    PYTHONPATH=src python examples/seesaw_vs_cosine_lm.py [--model 150m]
"""
import argparse

import numpy as np

from repro.configs import OptimizerConfig, RunConfig, ScheduleConfig
from repro.configs.seesaw_paper import CBS, SEESAW_150M, paper_run
from repro.data import MarkovLM, PhaseDataLoader
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="reduced",
                    choices=["reduced", "150m"])
    ap.add_argument("--steps", type=int, default=300,
                    help="0 = full Chinchilla token budget")
    ap.add_argument("--alpha", type=float, default=2.0,
                    help="paper's Table 1 uses 1.1; 2.0 is CPU-friendly")
    args = ap.parse_args()

    results = {}
    for kind in ("cosine", "seesaw"):
        if args.model == "150m":
            cfg = paper_run(SEESAW_150M, kind=kind, alpha=args.alpha)
            if args.steps:
                b0 = CBS["seesaw-150m"]
                cfg = RunConfig(
                    model=cfg.model, schedule=cfg.schedule,
                    optimizer=cfg.optimizer, seq_len=cfg.seq_len,
                    global_batch_size=b0,
                    total_tokens=args.steps * b0 * cfg.seq_len)
        else:
            model = SEESAW_150M.reduced()
            b0 = 16
            cfg = RunConfig(
                model=model,
                schedule=ScheduleConfig(kind=kind, base_lr=3e-3,
                                        warmup_frac=0.10,
                                        alpha=args.alpha, n_cuts=4),
                optimizer=OptimizerConfig(kind="adamw", beta1=0.9,
                                          beta2=0.95, eps=1e-8,
                                          weight_decay=0.0),
                seq_len=128, global_batch_size=b0,
                total_tokens=(args.steps or 300) * b0 * 128,
                remat=False)
        tr = Trainer(cfg)
        n_steps = tr.plan.total_steps(cfg.seq_len)
        print(f"\n{kind}: N={cfg.model.param_count()/1e6:.1f}M  "
              f"B0={cfg.global_batch_size}  {len(tr.plan.phases)} phases "
              f"→ {n_steps} serial steps, batches "
              f"{tr.plan.batch_sizes()}")
        src = MarkovLM(vocab_size=min(cfg.model.vocab_size, 2048), seed=0)
        loader = PhaseDataLoader(src, tr.plan, cfg.seq_len)
        hist = tr.run(loader, log_cb=lambda r: print(
            f"  step {r['step']:5d} B={r['batch_size']:4d} "
            f"lr={r['lr']:.2e} loss={r['loss']:.4f}"))
        results[kind] = hist

    h_c, h_s = results["cosine"], results["seesaw"]
    lc = np.mean([h["loss"] for h in h_c[-5:]])
    ls = np.mean([h["loss"] for h in h_s[-5:]])
    print(f"\n================= Figure-1 summary =================")
    print(f"cosine : {len(h_c):5d} steps  final loss {lc:.4f}  "
          f"tokens {h_c[-1]['tokens']:.3g}")
    print(f"seesaw : {len(h_s):5d} steps  final loss {ls:.4f}  "
          f"tokens {h_s[-1]['tokens']:.3g}")
    print(f"loss gap {abs(lc-ls):.4f} | serial-step reduction "
          f"{1 - len(h_s)/len(h_c):.1%} (Lemma-1 limit 36.3%)")


if __name__ == "__main__":
    main()
