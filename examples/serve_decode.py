"""Batched serving demo: prefill + KV-cache decode through the Server
runtime, on a reduced config of any assigned architecture.

    PYTHONPATH=src python examples/serve_decode.py --arch starcoder2-3b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import registry as R
from repro.train.serve import Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.arch_type in ("encdec", "audio"):
        print("note: enc-dec serving needs src embeddings; using the "
              "prefix stub")
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    srv = Server(cfg, params, max_len=args.prompt_len + args.new_tokens
                 + 8)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len))
    prefix = None
    if cfg.arch_type in ("vlm", "audio", "encdec"):
        prefix = jax.numpy.asarray(
            rng.normal(0, 1, (args.batch, cfg.frontend_tokens,
                              cfg.frontend_dim)), jax.numpy.bfloat16)
    t0 = time.time()
    out = srv.generate(prompts, args.new_tokens, prefix_emb=prefix,
                       temperature=args.temperature)
    dt = time.time() - t0
    print(f"arch={cfg.name}  batch={args.batch}  "
          f"prompt={args.prompt_len}  generated={args.new_tokens}")
    print(f"wall {dt:.2f}s  "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s batched)")
    for i, row in enumerate(out[:3]):
        print(f"  seq{i}: {row.tolist()}")


if __name__ == "__main__":
    main()
