"""Serving demo: submit ragged generation requests to the
continuous-batching ``ServingEngine`` and stream tokens as they land.

    PYTHONPATH=src python examples/serve_decode.py --arch llama3.2-3b

Families the engine cannot hold (ring-cache sliding windows, hybrid,
enc-dec) fall back to the blocking dense ``Server`` — the same typed
KV-cache API underneath, without continuous batching.
"""
import argparse
import time
import warnings

import jax
import numpy as np

from repro.configs import get_config
from repro.models import registry as R
from repro.serving import GenerationRequest, ServingEngine
from repro.train.serve import Server


def serve_engine(cfg, params, args):
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.new_tokens + 8
    eng = ServingEngine(cfg, params, decode_slots=args.batch,
                        max_len=max_len)
    for i in range(args.batch):
        s = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        n = int(rng.integers(max(args.new_tokens // 2, 1),
                             args.new_tokens + 1))
        prompt = rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32)
        rid = eng.submit(GenerationRequest(prompt=prompt,
                                           max_new_tokens=n))
        print(f"  submit rid={rid} prompt_len={s} max_new={n}")
    t0 = time.time()
    n_tok = 0
    while not eng.done:
        for rid, tok, fin in eng.step():      # streaming events
            n_tok += 1
            if fin:
                res = eng.result(rid)
                print(f"  rid={rid} done ({res.finish_reason}): "
                      f"{res.tokens.tolist()}")
    dt = time.time() - t0
    print(f"engine: {n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s),"
          f" {eng.executables} executables "
          f"(budget {eng.executable_budget}), "
          f"occupancy {eng.mean_occupancy():.2f}")


def serve_blocking(cfg, params, args):
    rng = np.random.default_rng(0)
    srv = Server(cfg, params,
                 max_len=args.prompt_len + args.new_tokens + 8)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len))
    prefix = None
    if cfg.arch_type in ("vlm", "audio", "encdec"):
        prefix = jax.numpy.asarray(
            rng.normal(0, 1, (args.batch, cfg.frontend_tokens,
                              cfg.frontend_dim)), jax.numpy.bfloat16)
    t0 = time.time()
    with warnings.catch_warnings():           # the known-legacy path
        warnings.simplefilter("ignore", DeprecationWarning)
        out = srv.generate(prompts, args.new_tokens, prefix_emb=prefix,
                           temperature=args.temperature)
    dt = time.time() - t0
    print(f"blocking Server: wall {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s batched)")
    for i, row in enumerate(out[:3]):
        print(f"  seq{i}: {row.tolist()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8,
                    help="blocking-Server fallback only; the engine "
                         "decodes greedily")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    mode = R.serving_mode(cfg)
    print(f"arch={cfg.name}  serving_mode={mode}")
    if mode is not None:
        serve_engine(cfg, params, args)
    else:
        serve_blocking(cfg, params, args)


if __name__ == "__main__":
    main()
