"""60-second tour: train a tiny LM with Seesaw vs cosine and see the
paper's effect — same loss trajectory in tokens, ~25% fewer serial steps
at this cut depth (→36% at the paper's α=1.1 depth, Lemma 1).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.data import MarkovLM, PhaseDataLoader
from repro.train.trainer import Trainer

MODEL = ModelConfig(name="quickstart", arch_type="dense", n_layers=2,
                    d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                    d_ff=256, vocab_size=512, max_seq_len=64,
                    rope_theta=1e4)


def train(kind: str):
    cfg = RunConfig(
        model=MODEL,
        schedule=ScheduleConfig(kind=kind, base_lr=3e-3, alpha=2.0,
                                n_cuts=4),
        optimizer=OptimizerConfig(kind="adamw", beta1=0.9, beta2=0.95),
        seq_len=64, global_batch_size=8, total_tokens=64 * 8 * 150,
        remat=False)
    tr = Trainer(cfg)
    print(f"\n=== {kind}: {len(tr.plan.phases)} phases, "
          f"batches {tr.plan.batch_sizes()} ===")
    loader = PhaseDataLoader(MarkovLM(512, seed=0), tr.plan, cfg.seq_len)
    hist = tr.run(loader, log_cb=lambda r: print(
        f"  step {r['step']:4d}  B={r['batch_size']:3d} "
        f"lr={r['lr']:.2e}  loss={r['loss']:.4f}"))
    return hist


if __name__ == "__main__":
    h_cos = train("cosine")
    h_see = train("seesaw")
    lc = np.mean([h["loss"] for h in h_cos[-5:]])
    ls = np.mean([h["loss"] for h in h_see[-5:]])
    print(f"\ncosine : {len(h_cos)} steps, final loss {lc:.4f}")
    print(f"seesaw : {len(h_see)} steps, final loss {ls:.4f}")
    print(f"serial-step reduction: {1 - len(h_see)/len(h_cos):.1%} "
          f"(Lemma 1 limit: 36.3%)")
