"""Theorem 1 / Corollary 1 numerics: LR decay ≡ batch ramp on noisy
linear regression, via the exact bias/variance recursions AND a real
sampled-SGD run (both directions of the equivalence).

    PYTHONPATH=src python examples/linear_regression_equivalence.py
"""
import math

import numpy as np

from repro.core import theory as T
from repro.data import LinearRegressionSampler


def exact_recursions():
    print("== exact recursions (Section 5) ==")
    lam = T.power_law_spectrum(100, a=1.0)
    eta = T.stability_eta(lam)
    m0 = T.warm_start(lam, 1.0, eta, 8, 2000)
    samples = [4096] * 6

    r = T.theorem1_risk_ratio(lam, 1.0, eta0=eta, b0=8, alpha1=4.0,
                              beta1=1.0, alpha2=2.0, beta2=2.0,
                              samples_per_phase=samples, m_start=m0)
    print(f"Theorem 1  (SGD,  αβ matched 4·1 = 2·2):   risk ratio {r:.4f}")

    eta_n = eta * math.sqrt(np.sum(lam) / 8)
    r = T.corollary1_risk_ratio(lam, 1.0, eta0=eta_n, b0=8, alpha1=2.0,
                                beta1=1.0, alpha2=math.sqrt(2), beta2=2.0,
                                samples_per_phase=samples, m_start=m0)
    print(f"Corollary 1 (NSGD, α√β matched 2 = √2·√2): risk ratio {r:.4f}")

    bad = T.theorem1_risk_ratio(lam, 1.0, eta0=eta, b0=8, alpha1=4.0,
                                beta1=1.0, alpha2=1.2, beta2=1.0,
                                samples_per_phase=samples, m_start=m0)
    print(f"mismatched products (4 vs 1.2):            risk ratio {bad:.4f}")


def sampled_sgd(seed: int = 0):
    print("\n== sampled SGD (same equivalence, real noise) ==")
    d = 50
    lam = T.power_law_spectrum(d, a=1.0)
    sampler = LinearRegressionSampler(lam, sigma2=0.25, seed=seed)
    rng = np.random.default_rng(seed)
    w0 = rng.normal(size=d) / np.sqrt(d)
    eta0 = T.stability_eta(lam) * 5

    def run(alpha, beta, phases=5, samples_per_phase=20000, b0=10):
        w = w0.copy()
        step_idx = 0
        for k in range(phases):
            B = int(b0 * beta ** k)
            eta = eta0 * alpha ** (-k)
            for _ in range(samples_per_phase // B):
                x, y = sampler.sample(step_idx, B)
                g = x.T @ (x @ w - y) / B
                w = w - eta * g
                step_idx += 1
        return sampler.risk(w), step_idx

    r1, s1 = run(4.0, 1.0)
    r2, s2 = run(2.0, 2.0)
    print(f"(α,β)=(4,1): risk {r1:.5f}  serial steps {s1}")
    print(f"(α,β)=(2,2): risk {r2:.5f}  serial steps {s2} "
          f"({1 - s2/s1:.0%} fewer)")
    print(f"ratio {r1/r2:.3f} (→ 1 means equivalent, Theorem 1)")


if __name__ == "__main__":
    exact_recursions()
    sampled_sgd()
