"""Beyond-paper extension demo: budget-free Seesaw.

The paper derives cut points from a reference cosine over a KNOWN total
token budget.  The adaptive controller instead fires each (√α LR cut,
×α batch ramp) when the smoothed loss plateaus — no budget needed —
while staying on the Corollary-1 equivalence line.  This demo trains
the same tiny LM three ways and compares.

    PYTHONPATH=src python examples/adaptive_seesaw.py
"""
import numpy as np

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.core.adaptive import AdaptiveSeesaw
from repro.data import MarkovLM, PhaseDataLoader
from repro.optim import optimizers as O
from repro.train.trainer import Trainer, make_train_step

import jax
import jax.numpy as jnp

MODEL = ModelConfig(name="adaptive-demo", arch_type="dense", n_layers=2,
                    d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                    d_ff=256, vocab_size=512, max_seq_len=64,
                    rope_theta=1e4)
SEQ, B0, STEPS = 64, 8, 150


def run_scheduled(kind):
    cfg = RunConfig(model=MODEL,
                    schedule=ScheduleConfig(kind=kind, base_lr=3e-3,
                                            alpha=2.0, n_cuts=4),
                    optimizer=OptimizerConfig(kind="adamw"),
                    seq_len=SEQ, global_batch_size=B0,
                    total_tokens=SEQ * B0 * STEPS, remat=False)
    tr = Trainer(cfg)
    hist = tr.run(PhaseDataLoader(MarkovLM(512, seed=0), tr.plan, SEQ))
    return hist


def run_adaptive():
    """Same trainer substrate, cuts chosen online."""
    cfg = RunConfig(model=MODEL,
                    schedule=ScheduleConfig(kind="constant", base_lr=3e-3),
                    optimizer=OptimizerConfig(kind="adamw"),
                    seq_len=SEQ, global_batch_size=B0,
                    total_tokens=SEQ * B0 * STEPS, remat=False)
    from repro.models import registry as R
    opt = O.from_config(cfg.optimizer)
    params = R.init_params(jax.random.PRNGKey(cfg.seed), MODEL)
    opt_state = opt.init(params)
    ctl = AdaptiveSeesaw(alpha=2.0, window=8, rel_threshold=8e-3,
                         min_steps_between=10, max_cuts=4)
    src = MarkovLM(512, seed=0)
    steps = {}
    tokens = seq_cursor = 0
    total = SEQ * B0 * STEPS
    hist = []
    warmup_tokens = 0.1 * total
    while tokens < total:
        B = int(B0 * ctl.batch_multiplier)
        fn = steps.setdefault(B, jax.jit(
            make_train_step(cfg, opt), donate_argnums=(0, 1)))
        batch = {k: jnp.asarray(v) for k, v in
                 src.sample(seq_cursor, B, SEQ).items()}
        seq_cursor += B
        warm = min(tokens / max(warmup_tokens, 1), 1.0)
        lr = cfg.schedule.base_lr * warm * ctl.lr_scale
        params, opt_state, metrics = fn(params, opt_state, batch,
                                        jnp.asarray(lr, jnp.float32))
        tokens += B * SEQ
        loss = float(metrics["loss"])
        hist.append({"loss": loss, "batch_size": B, "tokens": tokens})
        if tokens > warmup_tokens:
            ctl.observe(loss)
    return hist, ctl


if __name__ == "__main__":
    h_cos = run_scheduled("cosine")
    h_see = run_scheduled("seesaw")
    h_ada, ctl = run_adaptive()
    f = lambda h: np.mean([x["loss"] for x in h[-5:]])
    print(f"cosine            : {len(h_cos):4d} steps  loss {f(h_cos):.4f}")
    print(f"seesaw (scheduled): {len(h_see):4d} steps  loss {f(h_see):.4f}")
    print(f"seesaw (adaptive) : {len(h_ada):4d} steps  loss {f(h_ada):.4f}"
          f"  cuts at steps {ctl.cut_steps} "
          f"(final batch {int(B0 * ctl.batch_multiplier)})")
    print("\nadaptive needs no token budget: cuts fire on loss plateaus,"
          "\nstaying on the Corollary-1 line (alpha_s*sqrt(beta) = alpha).")
