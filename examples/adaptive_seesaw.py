"""Beyond-paper extension demo: budget-free Seesaw.

The paper derives cut points from a reference cosine over a KNOWN total
token budget.  The adaptive controller instead fires each (√α LR cut,
×α batch ramp) when the smoothed loss plateaus — no budget needed —
while staying on the Corollary-1 equivalence line.  This demo trains
the same tiny LM three ways and compares.

Since PR 8 the adaptive controller is a production schedule kind: the
fused step accumulates a loss EMA on device, the trainer tests it at
chunk boundaries, and a cut extends the plan and re-chunks the loader
mid-stream (see docs/adaptive.md).  ``run_adaptive`` below is just the
ordinary Trainer with ``kind="adaptive-seesaw"``.

    PYTHONPATH=src python examples/adaptive_seesaw.py
"""
import numpy as np

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.data import MarkovLM, PhaseDataLoader
from repro.train.trainer import Trainer

MODEL = ModelConfig(name="adaptive-demo", arch_type="dense", n_layers=2,
                    d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                    d_ff=256, vocab_size=512, max_seq_len=64,
                    rope_theta=1e4)
SEQ, B0, STEPS = 64, 8, 150


def run_scheduled(kind):
    cfg = RunConfig(model=MODEL,
                    schedule=ScheduleConfig(kind=kind, base_lr=3e-3,
                                            alpha=2.0, n_cuts=4),
                    optimizer=OptimizerConfig(kind="adamw"),
                    seq_len=SEQ, global_batch_size=B0,
                    total_tokens=SEQ * B0 * STEPS, remat=False)
    tr = Trainer(cfg)
    hist = tr.run(PhaseDataLoader(MarkovLM(512, seed=0), tr.plan, SEQ))
    return hist


def run_adaptive():
    """Same trainer substrate, cuts chosen online by the device EMA."""
    cfg = RunConfig(model=MODEL,
                    schedule=ScheduleConfig(kind="adaptive-seesaw",
                                            base_lr=3e-3, alpha=2.0,
                                            n_cuts=4, ema_decay=0.9,
                                            plateau_window=8,
                                            plateau_threshold=8e-3),
                    optimizer=OptimizerConfig(kind="adamw"),
                    seq_len=SEQ, global_batch_size=B0,
                    total_tokens=SEQ * B0 * STEPS, remat=False)
    tr = Trainer(cfg)
    hist = tr.run(PhaseDataLoader(MarkovLM(512, seed=0), tr.plan, SEQ))
    return hist, tr.controller


if __name__ == "__main__":
    h_cos = run_scheduled("cosine")
    h_see = run_scheduled("seesaw")
    h_ada, ctl = run_adaptive()
    f = lambda h: np.mean([x["loss"] for x in h[-5:]])
    print(f"cosine            : {len(h_cos):4d} steps  loss {f(h_cos):.4f}")
    print(f"seesaw (scheduled): {len(h_see):4d} steps  loss {f(h_see):.4f}")
    print(f"seesaw (adaptive) : {len(h_ada):4d} steps  loss {f(h_ada):.4f}"
          f"  cuts at steps {ctl.cut_steps} "
          f"(final batch {int(B0 * ctl.batch_multiplier)})")
    print("\nadaptive needs no token budget: cuts fire on loss plateaus,"
          "\nstaying on the Corollary-1 line (alpha_s*sqrt(beta) = alpha).")
