"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state, so tests/benches keep their 1-CPU world while
the dry-run (which sets xla_force_host_platform_device_count=512 before
any import) builds the real topology.

Target hardware: TPU v5e pods — 256 chips/pod, (16, 16) ICI torus;
multi-pod adds a leading 'pod' axis over DCN.
"""
from __future__ import annotations

import jax

# TPU v5e per-chip constants used by the roofline (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link


def data_parallel_size(mesh) -> int:
    """Number of data-parallel shards of the global batch: the product
    of the mesh's 'pod' and 'data' axes (1 when no mesh).  Accepts any
    duck-typed object exposing a ``.shape`` mapping, so the engine and
    per-host plan validation share one definition of the data width."""
    if mesh is None:
        return 1
    shape = dict(mesh.shape)
    n = 1
    for axis in ("pod", "data"):
        n *= int(shape.get(axis, 1))
    return max(n, 1)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2,
                   multi_pod: bool = False):
    """Small mesh for CI-scale dry-run tests (8 host devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
