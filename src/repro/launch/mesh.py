"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state, so tests/benches keep their 1-CPU world while
the dry-run (which sets xla_force_host_platform_device_count=512 before
any import) builds the real topology.

Target hardware: TPU v5e pods — 256 chips/pod, (16, 16) ICI torus;
multi-pod adds a leading 'pod' axis over DCN.
"""
from __future__ import annotations

import jax

# TPU v5e per-chip constants used by the roofline (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link


def data_parallel_size(mesh) -> int:
    """Number of data-parallel shards of the global batch: the product
    of the mesh's 'pod' and 'data' axes (1 when no mesh).  Accepts any
    duck-typed object exposing a ``.shape`` mapping, so the engine and
    per-host plan validation share one definition of the data width."""
    if mesh is None:
        return 1
    shape = dict(mesh.shape)
    n = 1
    for axis in ("pod", "data"):
        n *= int(shape.get(axis, 1))
    return max(n, 1)


def _row_blocks_by_process(indices_map, n_rows: int):
    """{process_index: set of data-slot rows it owns} from a
    ``devices_indices_map`` of a length-``n_rows`` batch axis."""
    per: dict = {}
    for dev, idx in indices_map.items():
        sl = idx[0] if idx else slice(0, n_rows)
        start = sl.start or 0
        stop = n_rows if sl.stop is None else sl.stop
        per.setdefault(dev.process_index, set()).update(
            range(start, stop))
    return per


def check_per_host_row_blocks(per_process, n_rows: int,
                              process_count: int):
    """Pure check behind :func:`assert_per_host_row_blocks` (testable
    with synthetic layouts): process ``p`` must own exactly the
    contiguous slot block ``[p*n/N, (p+1)*n/N)`` — the layout the
    per-host loader samples (process p contributes rows
    ``[p*B/N, (p+1)*B/N)`` of every global batch)."""
    if n_rows % process_count:
        raise ValueError(
            f"data-parallel width {n_rows} does not divide across "
            f"{process_count} host processes — per-host feeding "
            f"cannot assign whole row blocks")
    per = n_rows // process_count
    for p in range(process_count):
        want = list(range(p * per, (p + 1) * per))
        got = sorted(per_process.get(p, ()))
        if got != want:
            raise ValueError(
                f"process {p} owns data-axis slots {got} but per-host "
                f"feeding requires the contiguous block "
                f"[{want[0]}, {want[-1] + 1}) in process order — this "
                f"mesh's device order breaks the loader's row-block "
                f"assumption (jax.make_mesh layouts satisfy it; custom "
                f"meshes must keep each process's devices contiguous "
                f"along the data axes)")


def assert_per_host_row_blocks(mesh, process_count: int | None = None):
    """Assert — from the actual ``NamedSharding``, not a mesh-builder
    heuristic — that each process owns one contiguous, process-ordered
    block of the batch (data) axis, so ``per_host=True`` feeding is
    safe on this mesh.  No-op for single-process runs or ``mesh=None``;
    raises ``ValueError`` on custom meshes whose device order would
    silently misassign rows."""
    nproc = (jax.process_count() if process_count is None
             else process_count)
    if mesh is None or nproc <= 1:
        return
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    n = data_parallel_size(mesh)
    axes = tuple(a for a in ("pod", "data") if a in dict(mesh.shape))
    sharding = NamedSharding(mesh, P(axes if axes else None))
    per = _row_blocks_by_process(sharding.devices_indices_map((n,)), n)
    check_per_host_row_blocks(per, n, nproc)


def make_launch_mesh(spec: str | None, *, distributed: bool = False):
    """The launcher's mesh from a ``--mesh`` spec ("DxM" data x model,
    or "PxDxM" pod x data x model), or the default multi-process
    topology — pure data parallelism over every global device — when
    ``distributed`` and no spec.  ``None`` (single-process, no spec)
    keeps the mesh-less fast path."""
    if spec:
        dims = [int(x) for x in spec.split("x")]
        names = ("data", "model")[:len(dims)] if len(dims) == 2 \
            else ("pod", "data", "model")
        return jax.make_mesh(tuple(dims), names)
    if distributed:
        return jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    return None


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2,
                   multi_pod: bool = False):
    """Small mesh for CI-scale dry-run tests (8 host devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
