import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes and extract the roofline terms.  The train
workload comes from the phase execution engine's step builder (via
``launch.steps.build_workload``) — the same compiled step the Trainer
dispatches, so the dry-run's memory/collective analysis describes the
real hot path.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k [--multipod] [--out artifacts/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Outputs one JSON per (arch, shape, mesh) under --out with:
  memory_analysis, cost_analysis (FLOPs/bytes), per-collective byte
  totals parsed from the optimized HLO, and wall compile time.
"""
import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config  # noqa: E402
from repro.launch import steps as ST     # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(bf16|f32|f16|f64|s32|s8|s16|s64|u8|u16|u32|u64|"
                       r"pred|f8e4m3|f8e5m2|c64|c128)\[([0-9,]*)\]")


def _bytes_of_shapes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum result bytes of every collective op in the optimized HLO, per
    collective kind, split by whether the op sits inside a loop body
    (lax.scan over layers ⇒ the roofline multiplies loop-body bytes by
    the trip count).  Result size ≈ bytes moved per device."""
    out = {k: 0 for k in _COLLECTIVES}
    out_loop = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    comp = ""
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # computation headers look like:  %name (args) -> type {   /  ENTRY ...
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", stripped)
        if m and stripped.endswith("{"):
            comp = m.group(2)
            continue
        if stripped == "}":
            comp = ""
            continue
        for kind in _COLLECTIVES:
            if re.search(rf"= .*\b{kind}-done\(", stripped):
                break  # bytes were counted at the matching -start
            if re.search(rf"= .*\b{kind}(-start)?\(", stripped):
                lhs = stripped.split("=", 1)[1]
                op_part = lhs.split("(", 1)[0]
                b = _bytes_of_shapes(op_part)
                in_loop = ("body" in comp) or ("while" in comp) \
                    or ("region" in comp)
                counts[kind] += 1
                if in_loop:
                    out_loop[kind] += b
                else:
                    out[kind] += b
                break
    return out, out_loop, counts


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            out_dir: str, block_skip: bool = False,
            seq_shard: bool = True, remat_policy: str = "",
            serve_resident: bool = False, capacity_factor: float = 0.0,
            cache_seq_shard: bool = False, mesh_shape: str = "",
            tag: str = "") -> dict:
    cfg = get_config(arch)
    if capacity_factor and cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=capacity_factor))
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "mode": shape.mode, "tag": tag or "baseline"}
    ok, why = ST.shape_supported(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        _save(rec, out_dir)
        return rec
    t0 = time.time()
    try:
        if mesh_shape:
            dims = tuple(int(x) for x in mesh_shape.split("x"))
            names = ("data", "model") if len(dims) == 2 else                 ("pod", "data", "model")
            mesh = jax.make_mesh(dims, names)
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args, in_specs, out_specs = ST.build_workload(
            cfg, shape, multi_pod=multi_pod, block_skip=block_skip,
            seq_shard=seq_shard, remat_policy=remat_policy,
            serve_resident=serve_resident,
            cache_seq_shard=cache_seq_shard)
        with mesh:
            in_sh = ST._named(mesh, in_specs)
            out_sh = ST._named(mesh, out_specs)
            # donate params/opt (train) or cache (decode) exactly like the
            # real runtime — without aliasing, XLA double-buffers the
            # largest arrays and memory_analysis overstates the footprint
            donate = (0, 1) if shape.mode == "train" else (
                (1,) if shape.mode == "decode" else ())
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        rec["status"] = "ok"
        rec["lower_s"] = round(t_lower, 1)
        rec["compile_s"] = round(t_compile, 1)
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in dir(ma)
                if k.endswith("_size_in_bytes") and not k.startswith("_")}
        except Exception as e:        # CPU backend may not implement
            rec["memory_analysis"] = {"error": str(e)[:200]}
        try:
            ca = compiled.cost_analysis()
            rec["cost_analysis"] = {
                k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and
                (k in ("flops", "bytes accessed", "optimal_seconds") or
                 k.startswith("bytes accessed"))}
        except Exception as e:
            rec["cost_analysis"] = {"error": str(e)[:200]}
        try:
            hlo = compiled.as_text()
            cb, cl, cc = collective_bytes(hlo)
            rec["collective_bytes"] = cb
            rec["collective_bytes_in_loop"] = cl
            rec["collective_counts"] = cc
            rec["hlo_lines"] = hlo.count("\n")
        except Exception as e:
            rec["collective_bytes"] = {"error": str(e)[:200]}
        print(f"OK   {arch:26s} {shape_name:12s} {mesh_name:8s} "
              f"compile={rec.get('compile_s', '?')}s")
        del compiled, lowered, jitted

    except Exception as e:
        rec["status"] = "error"
        rec["error"] = traceback.format_exc()[-2000:]
        print(f"FAIL {arch:26s} {shape_name:12s} {mesh_name}: "
              f"{str(e)[:200]}")
    _save(rec, out_dir)
    # XLA CPU retains compiled executables in process-level caches —
    # clear them or a long sweep OOMs (observed at ~33 GB RSS).
    jax.clear_caches()
    import gc
    gc.collect()
    return rec


def _save(rec: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    tag = rec.get("tag", "baseline")
    suffix = "" if tag == "baseline" else f".{tag}"
    path = os.path.join(
        out_dir, f"{rec['arch']}.{rec['shape']}.{rec['mesh']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--block-skip", action="store_true",
                    help="enable triangular-blocking attention (perf)")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--remat-policy", default="")
    ap.add_argument("--serve-resident", action="store_true")
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--cache-seq-shard", action="store_true")
    ap.add_argument("--mesh-shape", default="",
                    help="override mesh, e.g. 32x8 (data x model)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        archs = ASSIGNED_ARCHS
        shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    else:
        assert args.arch and args.shape
        archs = [args.arch]
        shapes = [args.shape]

    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multipod]
    results = []
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                suffix = "" if not args.tag else f".{args.tag}"
                path = os.path.join(
                    args.out, f"{arch}.{shp}.{mesh_name}{suffix}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"SKIP {arch} {shp} {mesh_name} (cached)")
                        results.append(prev)
                        continue
                results.append(run_one(
                    arch, shp, multi_pod=mp, out_dir=args.out,
                    block_skip=args.block_skip,
                    seq_shard=not args.no_seq_shard,
                    remat_policy=args.remat_policy,
                    serve_resident=args.serve_resident,
                    capacity_factor=args.capacity_factor,
                    cache_seq_shard=args.cache_seq_shard,
                    mesh_shape=args.mesh_shape,
                    tag=args.tag))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"/ {len(results)} total")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
