"""Production training launcher, driving the phase execution engine.

    PYTHONPATH=src python -m repro.launch.train --arch seesaw-150m \
        --schedule seesaw --steps 200 [--mesh 2x2] [--multipod] \
        [--fuse-steps 16] [--checkpoint ckpt.npz] [--resume] \
        [--per-host]

``--per-host`` turns on multi-host data feeding: each process samples
only its ``jax.process_index()`` shard of the global batch and the
global arrays are assembled across processes
(``jax.make_array_from_process_local_data``); the ramp is validated up
front so every phase's batch divides over processes and data devices.

On real hardware the mesh comes from the platform; on this container a
small host-device mesh (--host-devices N) exercises the identical pjit
path.  The runtime is the same ``Trainer``/``PhaseEngine`` stack the
quickstart example uses: per-phase compile cache, batch ramp, LR curve
evaluated on device, ``--fuse-steps K`` batches per host dispatch, and
phase-aware checkpointing (``--resume`` repositions the data stream on
the exact step boundary of the saved run).
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="seesaw-150m")
    ap.add_argument("--schedule", default="seesaw",
                    choices=["seesaw", "cosine", "step", "constant",
                             "seesaw-general", "naive-ramp"])
    ap.add_argument("--alpha", type=float, default=2.0)
    ap.add_argument("--beta", type=float, default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--batch-size", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--total-tokens", type=int, default=None)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant of the arch")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="simulate N devices on CPU (sets XLA_FLAGS)")
    ap.add_argument("--mesh", default=None, help="e.g. 2x2 = data x model")
    ap.add_argument("--z-loss", type=float, default=0.0)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="restore --checkpoint and continue the run")
    ap.add_argument("--fuse-steps", type=int, default=1,
                    help="K batches per fused dispatch (1 = eager)")
    ap.add_argument("--per-host", action="store_true",
                    help="each process feeds only its "
                         "jax.process_index() shard of the global "
                         "batch (multi-host data feeding)")
    ap.add_argument("--max-device-batch", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.host_devices}")

    import jax
    from repro.configs import (OptimizerConfig, RunConfig, ScheduleConfig,
                               get_config)
    from repro.data import MarkovLM, PhaseDataLoader
    from repro.train.trainer import Trainer

    model = get_config(args.arch)
    if args.reduced:
        model = model.reduced()
    seq_len = args.seq_len or min(model.max_seq_len, 1024)
    b0 = args.batch_size or 32
    total = args.total_tokens or (
        args.steps * b0 * seq_len if args.steps else 20 * model.param_count())

    cfg = RunConfig(
        model=model,
        schedule=ScheduleConfig(kind=args.schedule, base_lr=args.lr,
                                alpha=args.alpha,
                                beta=args.beta or args.alpha),
        optimizer=OptimizerConfig(kind=args.optimizer),
        seq_len=seq_len, global_batch_size=b0, total_tokens=total,
        z_loss=args.z_loss, seed=args.seed)

    mesh = None
    if args.mesh:
        dims = [int(x) for x in args.mesh.split("x")]
        names = ("data", "model")[:len(dims)] if len(dims) == 2 \
            else ("pod", "data", "model")
        mesh = jax.make_mesh(tuple(dims), names)

    trainer = Trainer(cfg, mesh=mesh, fuse_steps=args.fuse_steps,
                      max_device_batch=args.max_device_batch)
    print(f"arch={model.name} N={model.param_count()/1e6:.0f}M "
          f"schedule={args.schedule} phases={len(trainer.plan.phases)} "
          f"steps={trainer.plan.total_steps(seq_len)} "
          f"batches={trainer.plan.batch_sizes()} "
          f"fuse_steps={trainer.fuse_steps}")
    if args.per_host:
        # fail fast if any phase of the ramp cannot shard over the
        # processes/devices (not just the phases the run starts in)
        from repro.launch.steps import validate_feeding
        validate_feeding(trainer.plan, mesh)
        print(f"per-host feeding: process {jax.process_index()}"
              f"/{jax.process_count()}, local batch shards "
              f"{[b // jax.process_count() for b in trainer.plan.batch_sizes()]}")
    src = MarkovLM(vocab_size=min(model.vocab_size, 2048), seed=args.seed)
    loader = PhaseDataLoader(src, trainer.plan, seq_len, mesh=mesh,
                             per_host=args.per_host)
    if args.resume:
        assert args.checkpoint, "--resume needs --checkpoint"
        meta = trainer.restore_checkpoint(args.checkpoint)
        loader.resume(trainer.state.tokens_seen)
        print(f"resumed step {trainer.state.step} "
              f"(phase {meta.get('phase')}, B={meta.get('batch_size')}, "
              f"tokens {trainer.state.tokens_seen:.0f})")

    def log(rec):
        print(f"step {rec['step']:5d} phase {rec['phase']} "
              f"B={rec['batch_size']:4d} lr={rec['lr']:.2e} "
              f"loss={rec['loss']:.4f} ({rec['wall']:.1f}s)")

    hist = trainer.run(loader, max_steps=args.steps, log_cb=log)
    if hist:
        print(f"done: {len(hist)} steps, final loss "
              f"{hist[-1]['loss']:.4f}")
    else:
        print("done: nothing to run (plan already consumed)")
    if args.checkpoint:
        trainer.save_checkpoint(args.checkpoint)
        print(f"checkpoint → {args.checkpoint}")


if __name__ == "__main__":
    main()
