"""Production training launcher, driving the phase execution engine.

    PYTHONPATH=src python -m repro.launch.train --arch seesaw-150m \
        --schedule seesaw --steps 200 [--mesh 2x2] [--multipod] \
        [--fuse-steps 16] [--checkpoint ckpt] [--resume] \
        [--per-host] [--coordinator HOST:PORT --num-processes N \
         --process-id I]

Multi-process launch: run the same command on every host with
``--coordinator`` (process 0's address), ``--num-processes`` and a
distinct ``--process-id`` — or the equivalent environment variables
``JAX_COORDINATOR_ADDRESS`` / ``JAX_NUM_PROCESSES`` /
``JAX_PROCESS_ID`` (flags win).  :func:`maybe_init_distributed` wires
``jax.distributed.initialize`` before any device use and is skipped
automatically for single-process runs; on CPU it selects the gloo
cross-process collective backend.  In a multi-process run per-host
data feeding is forced on, the default mesh spans all global devices
as ``(device_count, 1) = data x model``, and
``launch.mesh.assert_per_host_row_blocks`` verifies — from the actual
``NamedSharding`` — that each process owns a contiguous row block of
the data axis, so custom ``--mesh`` layouts that would misassign rows
fail fast instead of training on the wrong data.

``--per-host`` turns on multi-host data feeding: each process samples
only its ``jax.process_index()`` shard of the global batch and the
global arrays are assembled across processes
(``jax.make_array_from_process_local_data``); the ramp is validated up
front so every phase's batch divides over processes and data devices.

``--checkpoint`` names a sharded streaming checkpoint *directory*
(an atomically-committed ``manifest.json`` + one ``arrays/<gen>/*.npy``
per distinct global block; see :mod:`repro.train.checkpoint` and
``docs/checkpointing.md``): block writers are assigned round-robin
across every process holding an addressable replica, each streams its
blocks to disk in bounded chunks, and process 0 commits the manifest
in a single rename (an interrupted save leaves the previous checkpoint
restorable) — so save/restore never materializes a full replica per
host and legacy single-file ``.npz`` checkpoints still restore.
``--save-every N`` adds periodic saves at chunk boundaries —
asynchronous by default (the state is snapshotted on device and a
background writer streams it while training continues; ``--sync-save``
reverts to blocking saves), and ``--verify-restore`` checks every
block's crc32 against the manifest before resuming.

Elastic + preemption-safe operation: ``--resume`` restores onto
WHATEVER topology this launch has — the on-disk format is
topology-independent, the loader re-derives this host's feed shard
and stream position from the exact ``tokens_seen``, and the remainder
of the ramp is re-validated for the new process count (a clear error
names the first phase the new mesh cannot feed).  SIGTERM/SIGINT
request a best-effort final save at the next chunk boundary within a
``--grace`` deadline instead of dying mid-step, and
``jax.distributed.initialize`` retries with exponential backoff
(``--connect-attempts`` / ``--connect-backoff``) so a restarted pod
waits out a slow-to-restart coordinator.

On real hardware the mesh comes from the platform; on this container a
small host-device mesh (--host-devices N) exercises the identical pjit
path.  The runtime is the same ``Trainer``/``PhaseEngine`` stack the
quickstart example uses: per-phase compile cache, batch ramp, LR curve
evaluated on device, ``--fuse-steps K`` batches per host dispatch, and
phase-aware checkpointing (``--resume`` repositions the data stream on
the exact step boundary of the saved run).
"""
from __future__ import annotations

import argparse
import os
import signal
import time


class PreemptionGuard:
    """Turns SIGTERM/SIGINT into a cooperative stop request.

    ``install()`` replaces the handlers; the trainer polls
    :meth:`should_stop` at each chunk boundary, so the run always stops
    on an exact chunk boundary — the state a final grace save writes is
    bitwise-resumable.  In a multi-process run the stop decision is
    made *collectively* (an all-gather of the local flags): the
    preempted pod's signal stops every process at the same boundary,
    since a lone process leaving the loop would strand its peers in the
    next chunk's collectives.  :meth:`grace_remaining` counts down the
    save budget from the first signal."""

    def __init__(self, grace: float = 60.0):
        self.grace = float(grace)
        self._signaled_at: float | None = None
        self._prev: dict = {}

    def install(self, signals=(signal.SIGTERM, signal.SIGINT)):
        for s in signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def uninstall(self):
        for s, h in self._prev.items():
            signal.signal(s, h)
        self._prev.clear()

    def _handler(self, signum, frame):
        if self._signaled_at is None:
            self._signaled_at = time.monotonic()

    def requested(self) -> bool:
        """This process received a signal (local, collective-free)."""
        return self._signaled_at is not None

    def grace_remaining(self) -> float:
        if self._signaled_at is None:
            return self.grace
        return max(self.grace - (time.monotonic() - self._signaled_at),
                   0.0)

    def should_stop(self) -> bool:
        """Collective stop poll for the chunk loop — every process
        returns the same answer at the same boundary."""
        import jax
        if jax.process_count() <= 1:
            return self.requested()
        from jax.experimental import multihost_utils
        import numpy as np
        flags = multihost_utils.process_allgather(
            np.int32(1 if self.requested() else 0))
        return bool(np.any(flags))


def init_distributed_with_retry(init_fn, *, attempts: int = 4,
                                backoff: float = 1.0,
                                sleep=time.sleep, log=print):
    """Run ``init_fn`` (a zero-arg ``jax.distributed.initialize``
    closure) with exponential backoff: a restarted pod whose
    coordinator is still coming back up retries instead of crashing
    the whole relaunch.  Delays are ``backoff * 2**i``; the last
    failure propagates."""
    for i in range(max(int(attempts), 1)):
        try:
            return init_fn()
        except Exception as e:                 # noqa: BLE001
            if i + 1 >= attempts:
                raise
            delay = backoff * (2 ** i)
            log(f"jax.distributed.initialize failed "
                f"(attempt {i + 1}/{attempts}): {e}; retrying in "
                f"{delay:.1f}s")
            sleep(delay)


def maybe_init_distributed(coordinator=None, num_processes=None,
                           process_id=None, *,
                           connect_attempts: int = 1,
                           connect_backoff: float = 1.0) -> bool:
    """Wire ``jax.distributed.initialize`` from flags/environment;
    returns True when a multi-process runtime was initialized.

    Single-process runs (no coordinator, ``num_processes`` absent or
    1) skip initialization entirely, so the launcher keeps working
    with plain ``python -m repro.launch.train``.  Must be called
    before any jax device use.  On an explicitly-CPU platform
    (``JAX_PLATFORMS=cpu``) the gloo collective backend is selected —
    without it cross-process collectives on CPU fail at the first
    all-reduce."""
    env = os.environ
    coordinator = coordinator or env.get("JAX_COORDINATOR_ADDRESS")
    if num_processes is None and env.get("JAX_NUM_PROCESSES"):
        num_processes = int(env["JAX_NUM_PROCESSES"])
    if process_id is None and env.get("JAX_PROCESS_ID"):
        process_id = int(env["JAX_PROCESS_ID"])
    if not coordinator and not num_processes:
        return False
    if num_processes is not None and num_processes <= 1 \
            and not coordinator:
        return False
    if not (coordinator and num_processes and process_id is not None):
        raise ValueError(
            "multi-process launch needs all three of coordinator "
            "address, num_processes and process_id (flags or "
            "JAX_COORDINATOR_ADDRESS/JAX_NUM_PROCESSES/"
            f"JAX_PROCESS_ID); got coordinator={coordinator!r}, "
            f"num_processes={num_processes!r}, "
            f"process_id={process_id!r}")
    import jax
    if "cpu" in env.get("JAX_PLATFORMS", "").split(","):
        try:
            jax.config.update("jax_cpu_collectives_implementation",
                              "gloo")
        except (AttributeError, ValueError):   # jaxlib without gloo
            pass
    init_distributed_with_retry(
        lambda: jax.distributed.initialize(
            coordinator_address=coordinator,
            num_processes=num_processes, process_id=process_id),
        attempts=connect_attempts, backoff=connect_backoff)
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="seesaw-150m")
    ap.add_argument("--schedule", default="seesaw",
                    choices=["seesaw", "cosine", "step", "constant",
                             "seesaw-general", "naive-ramp",
                             "adaptive-seesaw"])
    ap.add_argument("--alpha", type=float, default=2.0)
    ap.add_argument("--beta", type=float, default=None)
    # adaptive-seesaw controller knobs (ignored by other schedules;
    # see docs/adaptive.md)
    ap.add_argument("--ema-decay", type=float, default=0.98,
                    help="device loss-EMA decay per step")
    ap.add_argument("--plateau-window", type=int, default=50,
                    help="steps per plateau test")
    ap.add_argument("--plateau-threshold", type=float, default=2e-3,
                    help="relative improvement below which a window "
                         "counts as a plateau")
    ap.add_argument("--plateau-min-steps", type=int, default=None,
                    help="minimum steps between cuts (default: one "
                         "plateau window)")
    ap.add_argument("--max-cuts", type=int, default=8,
                    help="adaptive: most cuts the controller may fire "
                         "(sizes the runtime LR table); prescheduled: "
                         "step-decay approximation depth")
    ap.add_argument("--max-batch-size", type=int, default=None,
                    help="hardware cap on the batch ramp")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--batch-size", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--total-tokens", type=int, default=None)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant of the arch")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="simulate N devices on CPU (sets XLA_FLAGS)")
    ap.add_argument("--mesh", default=None, help="e.g. 2x2 = data x model")
    ap.add_argument("--z-loss", type=float, default=0.0)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="restore --checkpoint and continue the run "
                         "(elastic: the process count/mesh may differ "
                         "from the saving run's)")
    ap.add_argument("--save-every", type=int, default=None,
                    help="periodic checkpoint every N steps (at chunk "
                         "boundaries), async by default")
    ap.add_argument("--sync-save", action="store_true",
                    help="block the step loop during periodic saves "
                         "instead of streaming from a writer thread")
    ap.add_argument("--verify-restore", action="store_true",
                    help="verify every block's crc32 against the "
                         "manifest before resuming")
    ap.add_argument("--grace", type=float, default=60.0,
                    help="seconds allowed for the final save after "
                         "SIGTERM/SIGINT")
    ap.add_argument("--connect-attempts", type=int, default=4,
                    help="jax.distributed.initialize retries (slow "
                         "coordinator restart)")
    ap.add_argument("--connect-backoff", type=float, default=1.0,
                    help="initial retry delay, doubled per attempt")
    ap.add_argument("--fuse-steps", type=int, default=1,
                    help="K batches per fused dispatch (1 = eager)")
    ap.add_argument("--per-host", action="store_true",
                    help="each process feeds only its "
                         "jax.process_index() shard of the global "
                         "batch (multi-host data feeding; forced on "
                         "in multi-process runs)")
    ap.add_argument("--coordinator", default=None,
                    help="process 0's host:port for "
                         "jax.distributed.initialize (or "
                         "JAX_COORDINATOR_ADDRESS)")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="total process count of the multi-process "
                         "run (or JAX_NUM_PROCESSES)")
    ap.add_argument("--process-id", type=int, default=None,
                    help="this process's index (or JAX_PROCESS_ID)")
    ap.add_argument("--max-device-batch", type=int, default=None)
    ap.add_argument("--kernel-backend", default=None,
                    choices=["xla", "pallas", "pallas_interpret"],
                    help="hot-path op backend (attention / rmsnorm / "
                         "SSD) the fused step compiles against; "
                         "default: the model config's (xla)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.host_devices}")

    distributed = maybe_init_distributed(
        args.coordinator, args.num_processes, args.process_id,
        connect_attempts=args.connect_attempts,
        connect_backoff=args.connect_backoff)

    import jax
    from repro.configs import (OptimizerConfig, RunConfig, ScheduleConfig,
                               get_config)
    from repro.data import MarkovLM, PhaseDataLoader
    from repro.train.trainer import Trainer

    if distributed:
        print(f"jax.distributed: process {jax.process_index()}"
              f"/{jax.process_count()}, "
              f"{jax.local_device_count()} local of "
              f"{jax.device_count()} global devices")
        if not args.per_host:
            # one process cannot feed (or even address) the whole
            # global batch in a real multi-process run
            args.per_host = True
            print("per-host data feeding forced on (multi-process)")

    model = get_config(args.arch)
    if args.reduced:
        model = model.reduced()
    seq_len = args.seq_len or min(model.max_seq_len, 1024)
    b0 = args.batch_size or 32
    total = args.total_tokens or (
        args.steps * b0 * seq_len if args.steps else 20 * model.param_count())

    cfg = RunConfig(
        model=model,
        schedule=ScheduleConfig(kind=args.schedule, base_lr=args.lr,
                                alpha=args.alpha,
                                beta=args.beta or args.alpha,
                                n_cuts=args.max_cuts,
                                max_batch_size=args.max_batch_size,
                                ema_decay=args.ema_decay,
                                plateau_window=args.plateau_window,
                                plateau_threshold=args.plateau_threshold,
                                plateau_min_steps=args.plateau_min_steps),
        optimizer=OptimizerConfig(kind=args.optimizer),
        seq_len=seq_len, global_batch_size=b0, total_tokens=total,
        z_loss=args.z_loss, seed=args.seed,
        kernel_backend=args.kernel_backend)

    from repro.launch.mesh import make_launch_mesh
    mesh = make_launch_mesh(args.mesh, distributed=distributed)

    trainer = Trainer(cfg, mesh=mesh, fuse_steps=args.fuse_steps,
                      max_device_batch=args.max_device_batch)
    print(f"arch={model.name} N={model.param_count()/1e6:.0f}M "
          f"schedule={args.schedule} phases={len(trainer.plan.phases)} "
          f"steps={trainer.plan.total_steps(seq_len)} "
          f"batches={trainer.plan.batch_sizes()} "
          f"fuse_steps={trainer.fuse_steps}")
    start_tokens = None
    if args.resume:
        # restore BEFORE ramp validation: an elastic resume (new
        # process count) only has to feed the ramp from the restored
        # position on, and that position comes from the checkpoint
        assert args.checkpoint, "--resume needs --checkpoint"
        meta = trainer.restore_checkpoint(args.checkpoint,
                                          verify=args.verify_restore)
        start_tokens = trainer.state.tokens_seen
        print(f"resumed step {trainer.state.step} "
              f"(phase {meta.get('phase')}, B={meta.get('batch_size')}, "
              f"tokens {trainer.state.tokens_seen:.0f}, saved from "
              f"{meta.get('save_process_count', '?')} processes)")
    if args.per_host:
        # fail fast if any phase still ahead cannot shard over the
        # processes/devices (from the resume point in elastic resumes;
        # the whole ramp otherwise)
        from repro.launch.steps import validate_feeding
        validate_feeding(trainer.plan, mesh, start_tokens=start_tokens,
                         seq_len=seq_len)
        print(f"per-host feeding: process {jax.process_index()}"
              f"/{jax.process_count()}, local batch shards "
              f"{[b // jax.process_count() for b in trainer.plan.batch_sizes()]}")
    src = MarkovLM(vocab_size=min(model.vocab_size, 2048), seed=args.seed)
    loader = PhaseDataLoader(src, trainer.plan, seq_len, mesh=mesh,
                             per_host=args.per_host,
                             validate=not args.resume)
    if args.resume:
        loader.resume(start_tokens)

    def log(rec):
        print(f"step {rec['step']:5d} phase {rec['phase']} "
              f"B={rec['batch_size']:4d} lr={rec['lr']:.2e} "
              f"loss={rec['loss']:.4f} ({rec['wall']:.1f}s)")

    guard = PreemptionGuard(grace=args.grace).install()
    try:
        hist = trainer.run(loader, max_steps=args.steps, log_cb=log,
                           checkpoint_path=args.checkpoint,
                           save_every=args.save_every,
                           async_save=not args.sync_save,
                           stop_fn=guard.should_stop)
    finally:
        guard.uninstall()
    if guard.requested():
        print(f"preemption signal: stopped at step "
              f"{trainer.state.step} (chunk boundary)")
    if hist:
        print(f"done: {len(hist)} steps, final loss "
              f"{hist[-1]['loss']:.4f}")
    else:
        print("done: nothing to run (plan already consumed)")
    if args.checkpoint:
        if guard.requested() and guard.grace_remaining() <= 0:
            print("grace deadline exceeded — skipping the final save "
                  "(the last periodic checkpoint is the resume point)")
        else:
            trainer.save_checkpoint(args.checkpoint)
            print(f"checkpoint → {args.checkpoint}")
    trainer.close()


if __name__ == "__main__":
    main()
