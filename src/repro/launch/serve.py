"""Serving launcher: drive the continuous-batching engine from the CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        [--requests 16] [--decode-slots 4] [--page-size 16] \
        [--max-len 256] [--max-new 32] [--seed 0] \
        [--kernel-backend xla|pallas|pallas_interpret]

Builds a reduced config of the named architecture, submits a seeded
batch of ragged requests (prompt lengths and generation budgets drawn
per request), streams tokens as the engine emits them, and reports the
drain throughput plus the serving compile invariant (one prefill
executable per prompt bucket, one decode executable total).  The
counterpart of ``repro.launch.train`` for the serving subsystem; for a
load sweep with latency percentiles and the static-batch comparison,
use ``benchmarks/bench_serve.py``.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import registry as R
from repro.serving import GenerationRequest, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--decode-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel-backend", default=None,
                    choices=("xla", "pallas", "pallas_interpret"))
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-request completion lines")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    if args.kernel_backend:
        cfg = dataclasses.replace(cfg,
                                  kernel_backend=args.kernel_backend)
    mode = R.serving_mode(cfg)
    if mode is None:
        raise SystemExit(
            f"arch {cfg.name} (arch_type={cfg.arch_type}, window="
            f"{cfg.sliding_window}) has no paged/state serving mode; "
            f"use train.serve.Server (examples/serve_decode.py falls "
            f"back automatically)")
    params = R.init_params(jax.random.PRNGKey(args.seed), cfg)
    eng = ServingEngine(cfg, params, decode_slots=args.decode_slots,
                        page_size=args.page_size, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    max_prompt = max(args.max_len - args.max_new, 2)
    for _ in range(args.requests):
        s = int(rng.integers(2, max_prompt + 1))
        n = int(rng.integers(1, args.max_new + 1))
        eng.submit(GenerationRequest(
            prompt=rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32),
            max_new_tokens=n))

    print(f"arch={cfg.name} mode={mode} slots={args.decode_slots} "
          f"page_size={eng.page_size} pool={eng.pool.capacity} pages")
    t0 = time.time()
    n_tok = 0
    while not eng.done:
        for rid, _tok, fin in eng.step():
            n_tok += 1
            if fin and not args.quiet:
                res = eng.result(rid)
                print(f"  rid={rid} {res.finish_reason} "
                      f"prompt={res.prompt_len} new={len(res.tokens)}")
    dt = time.time() - t0
    print(f"{args.requests} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / max(dt, 1e-9):.1f} tok/s)")
    print(f"executables: prefill={eng.n_prefill_executables} "
          f"decode={eng.n_decode_executables} "
          f"(budget {eng.executable_budget}); "
          f"occupancy {eng.mean_occupancy():.2f}")
    assert eng.n_decode_executables == 1, "decode executable invariant"


if __name__ == "__main__":
    main()
