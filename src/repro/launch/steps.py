"""Workload wiring for the dry-run and the real launcher: builds
(fn, arg structs, in/out shardings) per (arch × shape × mesh) without
allocating anything (jax.eval_shape for params/opt state).

The train step itself is NOT defined here: it comes from the phase
execution engine (``repro.train.engine.make_grad_step``), the single
``value_and_grad`` call site shared with ``Trainer`` — this module only
pairs it with eval-shape structs and sharding trees.  The sharding-tree
helpers (``param_structs`` / ``opt_structs`` / ``opt_state_specs`` /
``_named``) are re-exports from the engine.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import registry as R
from repro.train.engine import (make_grad_step, named_shardings,
                                opt_state_specs, opt_structs,
                                param_structs)

# long_500k requires sub-quadratic decoding (DESIGN.md §6)
LONG_CONTEXT_ARCHS = {"recurrentgemma-9b", "mamba2-2.7b", "starcoder2-3b"}

_named = named_shardings        # legacy name used by dryrun and tests


def validate_feeding(plan, mesh, *, process_count: int | None = None,
                     start_tokens=None, seq_len: int | None = None):
    """Dry-run/launch check that a plan's batch ramp is feedable on
    this topology: every phase's global batch must divide across the
    host processes (per-host data feeding) and across the mesh's
    data-parallel devices, and each process must own a contiguous,
    process-ordered row block of the data axes (asserted from the
    actual ``NamedSharding``, so custom meshes are covered).

    ``start_tokens`` (a checkpoint's exact ``tokens_seen``) turns this
    into the *elastic-resume* check: only the ramp from the phase that
    token count lands in onward must be feedable — the new topology
    may differ from the saving one, and phases the checkpoint already
    consumed don't constrain it.  With ``seq_len`` the phase is looked
    up on the realized (step-quantized) boundaries the loader uses;
    without it, on the plan's ideal token boundaries.  Raises
    ``ValueError`` on the first violation; returns the plan
    otherwise."""
    from repro.data.pipeline import validate_per_host_plan
    from repro.launch.mesh import (assert_per_host_row_blocks,
                                   data_parallel_size)
    n_proc = jax.process_count() if process_count is None \
        else process_count
    if mesh is not None:
        assert_per_host_row_blocks(mesh, n_proc)
    start_phase = 0
    if start_tokens is not None:
        from repro.train.checkpoint import exact_tokens
        tok = exact_tokens(start_tokens)
        ph = (plan.realized_phase_at(tok, seq_len) if seq_len
              else plan.phase_at_tokens(tok))
        start_phase = ph.index
    return validate_per_host_plan(plan, n_proc,
                                  data_parallel_size(mesh),
                                  start_phase=start_phase)


def shape_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_ARCHS:
        return False, ("skipped: full-attention arch at 500k decode "
                       "(see DESIGN.md §6)")
    return True, ""


def build_workload(cfg: ModelConfig, shape: InputShape, *,
                   multi_pod: bool = False, opt_kind: str = "adamw",
                   z_loss: float = 0.0, remat: bool = True,
                   block_skip: bool = False, seq_shard: bool = True,
                   remat_policy: str = "", serve_resident: bool = False,
                   cache_seq_shard: bool = False,
                   dtype=jnp.bfloat16):
    """Returns (fn, args tuple of ShapeDtypeStructs, in_shardings tuple,
    out_shardings)."""
    pspec = R.param_specs(cfg, multi_pod,
                          serve_resident=(serve_resident and
                                          shape.mode != "train"))
    pstruct = param_structs(cfg)
    ispec = R.input_shardings(cfg, shape, multi_pod,
                              cache_seq_shard=cache_seq_shard)
    istruct = R.input_specs(cfg, shape)

    if shape.mode == "train":
        opt, ostruct = opt_structs(cfg, pstruct, opt_kind)
        ospec = opt_state_specs(pspec, ostruct)
        step = make_grad_step(cfg, opt, z_loss=z_loss, dtype=dtype,
                              remat=remat, multi_pod=multi_pod,
                              block_skip=block_skip, seq_shard=seq_shard,
                              remat_policy=remat_policy)

        def train_step(params, opt_state, batch, lr):
            new_params, new_opt, metrics = step(params, opt_state,
                                                batch, lr)
            return new_params, new_opt, metrics["loss"]

        args = (pstruct, ostruct, istruct,
                jax.ShapeDtypeStruct((), jnp.float32))
        in_specs = (pspec, ospec, ispec, P())
        out_specs = (pspec, ospec, P())
        return train_step, args, in_specs, out_specs

    if shape.mode == "prefill":
        def prefill_step(params, batch):
            tokens = batch["tokens"]
            prefix = batch.get("prefix_emb")
            logits, _cache = R.prefill(
                params, cfg, tokens, prefix_emb=prefix,
                cache_len_cap=shape.seq_len, dtype=dtype,
                multi_pod=multi_pod)
            return logits

        args = (pstruct, istruct)
        in_specs = (pspec, ispec)
        b = ispec["tokens"]
        out_specs = P(b[0], None, "model")
        return prefill_step, args, in_specs, out_specs

    # decode: the cache is a typed KVCache pytree carrying its own
    # per-request lengths (no scalar cache_len operand anymore)
    def serve_step(params, cache, token):
        logits, new_cache = R.decode_step(
            params, cfg, cache, token, dtype=dtype, multi_pod=multi_pod)
        return logits, new_cache

    args = (pstruct, istruct["cache"], istruct["token"])
    in_specs = (pspec, ispec["cache"], ispec["token"])
    b = ispec["token"]
    out_specs = (P(b[0], None, "model"), ispec["cache"])
    return serve_step, args, in_specs, out_specs
