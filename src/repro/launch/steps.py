"""Workload step functions + sharding trees for the dry-run and the real
launcher: builds (fn, arg structs, in/out shardings) per (arch × shape ×
mesh) without allocating anything (jax.eval_shape for params/opt state).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import (InputShape, ModelConfig, OptimizerConfig)
from repro.models import registry as R
from repro.optim import optimizers as O

# long_500k requires sub-quadratic decoding (DESIGN.md §6)
LONG_CONTEXT_ARCHS = {"recurrentgemma-9b", "mamba2-2.7b", "starcoder2-3b"}


def shape_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_ARCHS:
        return False, ("skipped: full-attention arch at 500k decode "
                       "(see DESIGN.md §6)")
    return True, ""


def _named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def param_structs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: R.init_params(jax.random.PRNGKey(0), cfg))


def opt_structs(cfg: ModelConfig, params_struct, kind: str = "adamw"):
    opt = O.from_config(OptimizerConfig(kind=kind))
    return opt, jax.eval_shape(opt.init, params_struct)


def opt_state_specs(param_spec_tree, opt_state_struct):
    """Mirror param specs onto m/v slots; scalars replicated."""
    def spec_for(path_leaf, struct):
        return path_leaf

    out = {}
    for k, v in opt_state_struct.items():
        if k in ("m", "v", "mu"):
            out[k] = param_spec_tree
        else:
            out[k] = P()
    return out


def build_workload(cfg: ModelConfig, shape: InputShape, *,
                   multi_pod: bool = False, opt_kind: str = "adamw",
                   z_loss: float = 0.0, remat: bool = True,
                   block_skip: bool = False, seq_shard: bool = True,
                   remat_policy: str = "", serve_resident: bool = False,
                   cache_seq_shard: bool = False,
                   dtype=jnp.bfloat16):
    """Returns (fn, args tuple of ShapeDtypeStructs, in_shardings tuple,
    out_shardings)."""
    pspec = R.param_specs(cfg, multi_pod,
                          serve_resident=(serve_resident and
                                          shape.mode != "train"))
    pstruct = param_structs(cfg)
    ispec = R.input_shardings(cfg, shape, multi_pod,
                              cache_seq_shard=cache_seq_shard)
    istruct = R.input_specs(cfg, shape)

    if shape.mode == "train":
        opt, ostruct = opt_structs(cfg, pstruct, opt_kind)
        ospec = opt_state_specs(pspec, ostruct)

        def train_step(params, opt_state, batch, lr):
            def loss_of(p):
                return R.loss_fn(p, cfg, batch, z_loss=z_loss, dtype=dtype,
                                 remat=remat, multi_pod=multi_pod,
                                 block_skip=block_skip,
                                 seq_shard=seq_shard,
                                 remat_policy=remat_policy)

            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params)
            new_params, new_opt = opt.update(grads, opt_state, params, lr)
            return new_params, new_opt, metrics["loss"]

        args = (pstruct, ostruct, istruct,
                jax.ShapeDtypeStruct((), jnp.float32))
        in_specs = (pspec, ospec, ispec, P())
        out_specs = (pspec, ospec, P())
        return train_step, args, in_specs, out_specs

    if shape.mode == "prefill":
        def prefill_step(params, batch):
            tokens = batch["tokens"]
            prefix = batch.get("prefix_emb")
            logits, cache, ln = R.prefill(
                params, cfg, tokens, prefix_emb=prefix,
                cache_len_cap=shape.seq_len, dtype=dtype,
                multi_pod=multi_pod)
            return logits

        args = (pstruct, istruct)
        in_specs = (pspec, ispec)
        b = ispec["tokens"]
        out_specs = P(b[0], None, "model")
        return prefill_step, args, in_specs, out_specs

    # decode
    def serve_step(params, cache, cache_len, token):
        logits, new_cache, new_len = R.decode_step(
            params, cfg, cache, cache_len, token, dtype=dtype,
            multi_pod=multi_pod)
        return logits, new_cache, new_len

    args = (pstruct, istruct["cache"], istruct["cache_len"],
            istruct["token"])
    in_specs = (pspec, ispec["cache"], ispec["cache_len"], ispec["token"])
    b = ispec["token"]
    out_specs = (P(b[0], None, "model"), ispec["cache"], P())
    return serve_step, args, in_specs, out_specs
