"""Optimizers from scratch (no optax): SGD, normalized SGD (the paper's
Adam proxy, eq. 4), Adam, AdamW.  Functional optax-like triples:
``init(params) → state``, ``update(grads, state, params, lr) →
(updates, state)``; all states are pytrees that shard like the params.

NSGD implements  θ ← θ − η g/√(E‖g‖²)  with E‖g‖² estimated by the
global gradient norm of the batch (the batch-size dependence σ²Tr(H)/B
that powers Corollary 1 enters through this denominator).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig

Params = Any


class Optimizer(NamedTuple):
    init: Callable[[Params], Params]
    update: Callable[..., Tuple[Params, Params]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def _global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _clip_by_global_norm(grads, max_norm: float):
    if not max_norm:
        return grads, jnp.asarray(1.0)
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def sgd(momentum: float = 0.0, grad_clip: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            return {"mu": jax.tree.map(jnp.zeros_like, params),
                    "count": jnp.zeros((), jnp.int32)}
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        grads, gnorm = _clip_by_global_norm(grads, grad_clip)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g,
                              state["mu"], grads)
            params = jax.tree.map(lambda p, m: p - lr * m, params, mu)
            return params, {"mu": mu, "count": state["count"] + 1}
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return params, {"count": state["count"] + 1}

    return Optimizer(init, update)


def nsgd(grad_clip: float = 0.0, eps: float = 1e-12) -> Optimizer:
    """Normalized SGD: θ ← θ − η g/‖g‖ (global normalization — the
    scalar-preconditioner Adam proxy of paper eq. 4)."""

    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        norm = _global_norm(grads)
        scale = lr / jnp.maximum(norm, eps)
        params = jax.tree.map(lambda p, g: p - scale * g, params, grads)
        return params, {"count": state["count"] + 1}

    return Optimizer(init, update)


def adamw(beta1: float = 0.9, beta2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        grads, gnorm = _clip_by_global_norm(grads, grad_clip)
        c = state["count"] + 1
        bc1 = 1.0 - beta1 ** c.astype(jnp.float32)
        bc2 = 1.0 - beta2 ** c.astype(jnp.float32)
        m = jax.tree.map(lambda m, g: beta1 * m + (1 - beta1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: beta2 * v + (1 - beta2) * g * g,
                         state["v"], grads)

        def upd(p, m_, v_):
            mh = m_ / bc1
            vh = v_ / bc2
            step = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                step = step + weight_decay * p
            return p - lr * step

        params = jax.tree.map(upd, params, m, v)
        return params, {"m": m, "v": v, "count": c}

    return Optimizer(init, update)


def adam(beta1=0.9, beta2=0.95, eps=1e-8, grad_clip=1.0) -> Optimizer:
    return adamw(beta1, beta2, eps, 0.0, grad_clip)


def from_config(cfg: OptimizerConfig) -> Optimizer:
    if cfg.kind == "adamw":
        return adamw(cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay,
                     cfg.grad_clip)
    if cfg.kind == "adam":
        return adam(cfg.beta1, cfg.beta2, cfg.eps, cfg.grad_clip)
    if cfg.kind == "sgd":
        return sgd(0.0, cfg.grad_clip)
    if cfg.kind == "nsgd":
        return nsgd(cfg.grad_clip)
    raise ValueError(cfg.kind)


def init_opt_state(optimizer: Optimizer, params):
    return optimizer.init(params)


def update(optimizer: Optimizer, grads, state, params, lr):
    return optimizer.update(grads, state, params, lr)
