from repro.optim.optimizers import (adam, adamw, init_opt_state, nsgd,
                                    sgd, update)

__all__ = ["adam", "adamw", "init_opt_state", "nsgd", "sgd", "update"]
