from repro.data.synthetic import LinearRegressionSampler, MarkovLM
from repro.data.pipeline import PhaseDataLoader

__all__ = ["LinearRegressionSampler", "MarkovLM", "PhaseDataLoader"]
