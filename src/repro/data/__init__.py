from repro.data.synthetic import LinearRegressionSampler, MarkovLM
from repro.data.pipeline import PhaseDataLoader, validate_per_host_plan

__all__ = ["LinearRegressionSampler", "MarkovLM", "PhaseDataLoader",
           "validate_per_host_plan"]
