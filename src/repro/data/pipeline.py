"""Phase-aware data loading for the fused execution engine.

Follows a SeesawPlan's batch ramp, shards batches onto the mesh, and
guarantees equal-token data order across schedulers (same underlying
stream indexed by absolute sequence number, different batch
partitioning).  Two consumption modes:

- ``__iter__`` — one (phase, step, batch) at a time (legacy eager path
  and generic consumers);
- ``iter_chunks(k)`` — stacked (K, B, ...) same-phase chunks feeding
  the engine's K-step fused dispatch.

Both modes double-buffer: a daemon thread runs the (Python-loop-heavy)
synthetic sampling ahead of the consumer through a bounded queue, so
host data production overlaps device compute.  ``resume(tokens_seen)``
repositions the stream exactly on the step boundary a checkpoint was
saved at, in the correct phase.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.seesaw import SeesawPlan
from repro.data.synthetic import MarkovLM

_DONE = object()


class PhaseDataLoader:
    """Iterates a plan's (phase, step, batch) stream.

    The token stream is indexed by absolute sequence number, so a cosine
    run (constant B) and a Seesaw run (ramped B) consume identical
    sequences in identical order at equal token counts — and a resumed
    run continues the exact stream of the uninterrupted one.
    """

    def __init__(self, source: MarkovLM, plan: SeesawPlan, seq_len: int,
                 mesh=None, multi_pod: bool = False, prefetch: int = 2):
        self.source = source
        self.plan = plan
        self.seq_len = seq_len
        self.mesh = mesh
        self.multi_pod = multi_pod
        self.prefetch = prefetch
        # (phase_idx, steps_done_in_phase, absolute seq cursor)
        self._start: Tuple[int, int, int] = (0, 0, 0)

    # -- resume --------------------------------------------------------- #
    def position_at(self, tokens_seen: float) -> Tuple[int, int, int]:
        """(phase_idx, steps_done_in_phase, seq_cursor) for a token
        count that lies on a step boundary of the plan."""
        steps = self.plan.steps_per_phase(self.seq_len)
        tok = float(tokens_seen)
        cursor = 0
        for pi, (p, n) in enumerate(zip(self.plan.phases, steps)):
            per = p.batch_size * self.seq_len
            done = int(round(tok / per))
            if done < n:
                if abs(done * per - tok) > 0.5:
                    raise ValueError(
                        f"tokens_seen={tokens_seen} is not on a step "
                        f"boundary of phase {pi} (B={p.batch_size})")
                return pi, done, cursor + done * p.batch_size
            tok -= n * per
            cursor += n * p.batch_size
        return len(steps), 0, cursor

    def resume(self, tokens_seen: float) -> "PhaseDataLoader":
        """Reposition the stream to continue a checkpointed run."""
        self._start = self.position_at(tokens_seen)
        return self

    # -- sharding -------------------------------------------------------- #
    def _batch_axes(self):
        return ("pod", "data") if self.multi_pod else ("data",)

    def _shard(self, batch: Dict[str, np.ndarray], leading_dims: int = 1):
        """Device-put a host batch; dims before the batch dim (the K
        chunk dim) replicate, the batch dim shards over the data axes."""
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        axes = self._batch_axes()
        out = {}
        for k, v in batch.items():
            spec = P(*([None] * (leading_dims - 1)), axes,
                     *([None] * (v.ndim - leading_dims)))
            out[k] = jax.device_put(v, NamedSharding(self.mesh, spec))
        return out

    # -- host-side production ------------------------------------------- #
    def _host_steps(self) -> Iterator[Tuple[Any, int, Dict]]:
        steps = self.plan.steps_per_phase(self.seq_len)
        p0, s0, cursor = self._start
        for pi in range(p0, len(self.plan.phases)):
            phase, n = self.plan.phases[pi], steps[pi]
            for s in range(s0 if pi == p0 else 0, n):
                batch = self.source.sample(cursor, phase.batch_size,
                                           self.seq_len)
                cursor += phase.batch_size
                yield phase, s, batch

    def _host_chunks(self, k: int) -> Iterator[Tuple[Any, Dict, int]]:
        """Same stream, k same-phase steps at a time, sampled in one
        vectorized call and stacked to (m, B, ...)."""
        steps = self.plan.steps_per_phase(self.seq_len)
        p0, s0, cursor = self._start
        for pi in range(p0, len(self.plan.phases)):
            phase, n = self.plan.phases[pi], steps[pi]
            s = s0 if pi == p0 else 0
            while s < n:
                m = min(k, n - s)
                b = phase.batch_size
                raw = self.source.sample(cursor, m * b, self.seq_len)
                chunk = {key: v.reshape(m, b, *v.shape[1:])
                         for key, v in raw.items()}
                cursor += m * b
                s += m
                yield phase, chunk, m

    @staticmethod
    def _prefetched(gen, depth: int):
        """Run ``gen`` in a daemon thread, ``depth`` items ahead — the
        double buffer that overlaps sampling with device compute.  (An
        abandoned iterator parks the thread on the bounded queue; it is
        a daemon and holds at most ``depth`` batches.)"""
        q: queue.Queue = queue.Queue(maxsize=max(depth, 1))

        def worker():
            try:
                for item in gen:
                    q.put(item)
                q.put(_DONE)
            except BaseException as e:            # propagate to consumer
                q.put(e)

        threading.Thread(target=worker, daemon=True).start()
        while True:
            item = q.get()
            if item is _DONE:
                return
            if isinstance(item, BaseException):
                raise item
            yield item

    # -- consumption ---------------------------------------------------- #
    def __iter__(self) -> Iterator[Tuple[Any, int, Dict[str, Any]]]:
        gen = self._host_steps()
        if self.prefetch:
            gen = self._prefetched(gen, self.prefetch)
        for phase, s, batch in gen:
            yield phase, s, self._shard(batch)

    def iter_chunks(self, k: int) -> Iterator[Tuple[Any, Dict, int]]:
        """Yield (phase, stacked sharded chunk of m ≤ k steps, m) for
        the engine's fused dispatch."""
        gen = self._host_chunks(k)
        if self.prefetch:
            gen = self._prefetched(gen, self.prefetch)
        for phase, chunk, m in gen:
            yield phase, self._shard(chunk, leading_dims=2), m
