"""Phase-aware data loading for the fused execution engine.

Follows a SeesawPlan's batch ramp, shards batches onto the mesh, and
guarantees equal-token data order across schedulers (same underlying
stream indexed by absolute sequence number, different batch
partitioning).  Two consumption modes:

- ``__iter__`` — one (phase, step, batch) at a time (legacy eager path
  and generic consumers);
- ``iter_chunks(k)`` — stacked (K, B, ...) chunks feeding the engine's
  K-step fused dispatch.  The chunk stream is *phase-boundary-free*:
  adjacent phases with the same batch size (β=1 'step' plans, a ramp
  clamped by ``max_batch_size``) merge into one contiguous segment
  (the device LR is token/step-indexed, so crossing the boundary
  mid-chunk is exact), and the tail chunk of every segment is padded
  up to K by repeating its last step.  Consumers receive ``m`` — the
  number of *real* leading steps — and must pass it to the engine as
  ``n_valid``; the padded rows are masked on device.  Net effect: one
  compiled executable per distinct batch size, no remainder programs.

Two feeding modes:

- default — this process samples and owns the full global batch;
- ``per_host=True`` — each process samples only its
  ``jax.process_index()`` shard (a contiguous block of ``B /
  process_count`` rows per step) and the global (K, B, ...) arrays are
  assembled from the per-process blocks via
  ``jax.make_array_from_process_local_data``, which is what makes a
  real multi-host run feasible (one process can no longer feed the
  whole ramp).  Row blocks follow mesh device order, the standard
  layout ``jax.make_mesh`` produces on multi-host.  Pass an explicit
  ``process_count``/``process_index`` to *simulate* N-host feeding
  inside one process (mesh-less, host-level arrays only — the
  equivalence tests concatenate the simulated shards and compare
  against the single-process stream).

Both modes double-buffer: a daemon thread runs the (Python-loop-heavy)
synthetic sampling ahead of the consumer through a bounded queue, so
host data production overlaps device compute.  ``resume(tokens_seen)``
repositions the stream exactly on the step boundary a checkpoint was
saved at, in the correct phase.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.seesaw import SeesawPlan
from repro.data.synthetic import MarkovLM

_DONE = object()


def validate_per_host_plan(plan: SeesawPlan, process_count: int,
                           n_data_devices: int = 1, *,
                           start_phase: int = 0) -> SeesawPlan:
    """Check the per-host shard divides evenly across the whole ramp.

    Every phase's global batch must split into ``process_count`` equal
    per-process blocks, and still shard over all ``n_data_devices``
    data-parallel devices — a ramp that only divides in its early
    phases would crash mid-run, so this is validated up front (launch
    wiring and the dry-run both call it).  An elastic resume passes
    ``start_phase``: phases the checkpoint already consumed are skipped
    — the NEW topology only has to feed the remainder of the ramp, and
    a ramp stage it cannot feed is reported against the resume point,
    not a phase the run will never revisit."""
    suffix = (f" (resuming at phase {start_phase})"
              if start_phase > 0 else "")
    for p in plan.phases:
        if p.index < start_phase:
            continue
        if p.batch_size % max(process_count, 1):
            raise ValueError(
                f"phase {p.index}: global batch {p.batch_size} does "
                f"not divide across {process_count} host "
                f"processes{suffix}")
        if n_data_devices and p.batch_size % n_data_devices:
            raise ValueError(
                f"phase {p.index}: global batch {p.batch_size} does "
                f"not divide across {n_data_devices} data "
                f"devices{suffix}")
    return plan


class PhaseDataLoader:
    """Iterates a plan's (phase, step, batch) stream.

    The token stream is indexed by absolute sequence number, so a cosine
    run (constant B) and a Seesaw run (ramped B) consume identical
    sequences in identical order at equal token counts — and a resumed
    run continues the exact stream of the uninterrupted one.  In
    per-host mode the same invariant holds for the assembled *global*
    batch: process p contributes rows ``[p*B/N, (p+1)*B/N)`` of every
    step's global batch, so the concatenation over processes equals the
    single-process stream row for row.
    """

    def __init__(self, source: MarkovLM, plan: SeesawPlan, seq_len: int,
                 mesh=None, multi_pod: bool = False, prefetch: int = 2,
                 per_host: bool = False,
                 process_index: Optional[int] = None,
                 process_count: Optional[int] = None,
                 validate: bool = True):
        self.source = source
        self.plan = plan
        self.seq_len = seq_len
        self.mesh = mesh
        self.multi_pod = multi_pod
        self.prefetch = prefetch
        self.per_host = per_host
        if per_host:
            self._pcount = process_count or jax.process_count()
            self._pidx = (jax.process_index() if process_index is None
                          else process_index)
            # validate=False defers the whole-ramp check to resume():
            # an elastic resume onto a new topology must not fail on a
            # phase the checkpoint already consumed
            if validate:
                validate_per_host_plan(plan, self._pcount)
            if not 0 <= self._pidx < self._pcount:
                raise ValueError(
                    f"process_index {self._pidx} outside "
                    f"[0, {self._pcount})")
            if mesh is not None and self._pcount != jax.process_count():
                raise ValueError(
                    "a simulated process_count only makes sense "
                    "mesh-less (host-level arrays); with a mesh the "
                    "process layout comes from the jax runtime")
            if mesh is not None and self._pcount > 1:
                # verified from the actual NamedSharding, so per-host
                # feeding is safe on custom meshes too, not just the
                # layout jax.make_mesh produces
                from repro.launch.mesh import assert_per_host_row_blocks
                assert_per_host_row_blocks(mesh, self._pcount)
        else:
            self._pcount, self._pidx = 1, 0
        # (phase_idx, steps_done_in_phase, absolute seq cursor)
        self._start: Tuple[int, int, int] = (0, 0, 0)

    # -- resume --------------------------------------------------------- #
    def position_at(self, tokens_seen) -> Tuple[int, int, int]:
        """(phase_idx, steps_done_in_phase, seq_cursor) for a token
        count that lies on a step boundary of the plan.  Step
        boundaries are exact integers, so the arithmetic is integral
        (a float within 0.5 of a boundary is accepted for backward
        compatibility with f32-era checkpoints)."""
        from repro.train.checkpoint import exact_tokens
        steps = self.plan.steps_per_phase(self.seq_len)
        tok = exact_tokens(tokens_seen)
        cursor = 0
        for pi, (p, n) in enumerate(zip(self.plan.phases, steps)):
            per = p.batch_size * self.seq_len
            if tok < n * per:
                done, rem = divmod(tok, per)
                if rem:
                    raise ValueError(
                        f"tokens_seen={tokens_seen} is not on a step "
                        f"boundary of phase {pi} (B={p.batch_size})")
                return pi, done, cursor + done * p.batch_size
            tok -= n * per
            cursor += n * p.batch_size
        return len(steps), 0, cursor

    def resume(self, tokens_seen) -> "PhaseDataLoader":
        """Reposition the stream to continue a checkpointed run.  The
        remainder of the ramp is (re-)validated against THIS loader's
        topology from the resumed phase on — the elastic-resume check:
        the new process count need not match the saving one, but it
        must be able to feed every phase still ahead."""
        self._start = self.position_at(tokens_seen)
        if self.per_host:
            validate_per_host_plan(self.plan, self._pcount,
                                   start_phase=self._start[0])
        return self

    def rechunk(self, plan, tokens_seen) -> "PhaseDataLoader":
        """Swap in an extended plan mid-stream (an adaptive Seesaw cut)
        and reposition to the exact ``tokens_seen`` boundary.  The
        sequence stream is indexed by *absolute* sequence number, so
        the examples after the cut are the same ones the old plan would
        have produced — only the batch grouping changes.  A live
        ``iter_chunks`` generator keeps its creation-time position;
        create a fresh one after rechunking (the trainer's re-chunk
        loop does), and the old prefetch thread parks harmlessly on its
        queue.  Per-host feasibility of the *remaining* phases is
        re-validated, so a cut that creates an unfeedable ramp stage
        fails here — at cut time — rather than mid-ramp."""
        self.plan = plan
        return self.resume(tokens_seen)

    # -- sharding -------------------------------------------------------- #
    def _batch_axes(self):
        return ("pod", "data") if self.multi_pod else ("data",)

    def _shard(self, batch: Dict[str, np.ndarray], leading_dims: int = 1):
        """Put a host batch onto devices; dims before the batch dim
        (the K chunk dim) replicate, the batch dim shards over the data
        axes.  In per-host mode the local array is this process's row
        block and the global array is assembled across processes via
        ``jax.make_array_from_process_local_data``."""
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        axes = self._batch_axes()
        bdim = leading_dims - 1
        out = {}
        for k, v in batch.items():
            spec = P(*([None] * bdim), axes,
                     *([None] * (v.ndim - leading_dims)))
            sharding = NamedSharding(self.mesh, spec)
            if self.per_host:
                gshape = list(v.shape)
                gshape[bdim] = v.shape[bdim] * self._pcount
                out[k] = jax.make_array_from_process_local_data(
                    sharding, v, tuple(gshape))
            else:
                out[k] = jax.device_put(v, sharding)
        return out

    # -- host-side production ------------------------------------------- #
    def _local_rows(self, batch_size: int) -> Tuple[int, int]:
        """(row offset within the step's global batch, rows to sample)
        for this process — the whole batch outside per-host mode."""
        bl = batch_size // self._pcount
        return self._pidx * bl, bl

    def _host_steps(self) -> Iterator[Tuple[Any, int, Dict]]:
        steps = self.plan.steps_per_phase(self.seq_len)
        p0, s0, cursor = self._start
        for pi in range(p0, len(self.plan.phases)):
            phase, n = self.plan.phases[pi], steps[pi]
            for s in range(s0 if pi == p0 else 0, n):
                off, bl = self._local_rows(phase.batch_size)
                batch = self.source.sample(cursor + off, bl,
                                           self.seq_len)
                cursor += phase.batch_size
                yield phase, s, batch

    def _resume_segments(self):
        """The plan's merged same-batch-size segments with the resume
        offset applied (phases before the start dropped, the start
        phase's already-consumed steps removed)."""
        p0, s0, _ = self._start
        segs = []
        for b, entries in self.plan.merged_segments(self.seq_len):
            cur = []
            for phase, n in entries:
                if phase.index < p0:
                    continue
                if phase.index == p0:
                    n -= s0
                if n > 0:
                    cur.append((phase, n))
            if cur:
                segs.append((b, cur))
        return segs

    def _sample_chunk(self, cursor: int, m: int, b: int) -> Dict:
        """m steps × (this process's rows of) the global batch b,
        stacked to (m, local_b, ...)."""
        if self._pcount == 1:
            raw = self.source.sample(cursor, m * b, self.seq_len)
            return {key: v.reshape(m, b, *v.shape[1:])
                    for key, v in raw.items()}
        off, bl = self._local_rows(b)
        parts = [self.source.sample(cursor + s * b + off, bl,
                                    self.seq_len) for s in range(m)]
        return {key: np.stack([p[key] for p in parts])
                for key in parts[0]}

    def _host_chunks(self, k: int) -> Iterator[Tuple[Any, Dict, int]]:
        """The merged chunk stream: k steps at a time across each
        same-batch-size segment, the segment's tail chunk padded up to
        k by repeating its last step (padding consumes no cursor and is
        masked on device via ``n_valid``)."""
        _, _, cursor = self._start
        for b, entries in self._resume_segments():
            qi, qoff = 0, 0                 # phase pointer in segment
            remaining = sum(n for _, n in entries)
            while remaining:
                m = min(k, remaining)
                chunk = self._sample_chunk(cursor, m, b)
                if m < k:
                    chunk = {key: np.concatenate(
                        [v, np.repeat(v[-1:], k - m, axis=0)])
                        for key, v in chunk.items()}
                phase = entries[qi][0]      # phase of the chunk's head
                adv = m
                while adv:
                    take = min(adv, entries[qi][1] - qoff)
                    qoff += take
                    adv -= take
                    if qoff == entries[qi][1]:
                        qi, qoff = qi + 1, 0
                cursor += m * b
                remaining -= m
                yield phase, chunk, m

    @staticmethod
    def _prefetched(gen, depth: int):
        """Run ``gen`` in a daemon thread, ``depth`` items ahead — the
        double buffer that overlaps sampling with device compute.  (An
        abandoned iterator parks the thread on the bounded queue; it is
        a daemon and holds at most ``depth`` batches.)"""
        q: queue.Queue = queue.Queue(maxsize=max(depth, 1))

        def worker():
            try:
                for item in gen:
                    q.put(item)
                q.put(_DONE)
            except BaseException as e:            # propagate to consumer
                q.put(e)

        threading.Thread(target=worker, daemon=True).start()
        while True:
            item = q.get()
            if item is _DONE:
                return
            if isinstance(item, BaseException):
                raise item
            yield item

    # -- consumption ---------------------------------------------------- #
    def __iter__(self) -> Iterator[Tuple[Any, int, Dict[str, Any]]]:
        gen = self._host_steps()
        if self.prefetch:
            gen = self._prefetched(gen, self.prefetch)
        for phase, s, batch in gen:
            yield phase, s, self._shard(batch)

    def iter_chunks(self, k: int) -> Iterator[Tuple[Any, Dict, int]]:
        """Yield (phase of the first step, stacked sharded (k, B, ...)
        chunk, m) for the engine's fused dispatch.  Every chunk has
        leading dim exactly ``k``; only the first ``m`` steps are real
        — pass ``m`` to ``PhaseEngine.run_chunk`` as ``n_valid``.  A
        chunk may span a phase boundary (the merged stream): the batch
        size is constant within it, but per-step phase attribution must
        come from the token count, not the head phase tag."""
        gen = self._host_chunks(k)
        if self.prefetch:
            gen = self._prefetched(gen, self.prefetch)
        for phase, chunk, m in gen:
            yield phase, self._shard(chunk, leading_dims=2), m
