"""Phase-aware data loading: follows a SeesawPlan's batch ramp, shards
batches onto the mesh, and guarantees equal-token data order across
schedulers (same underlying stream, different batch partitioning)."""
from __future__ import annotations

from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.seesaw import SeesawPlan
from repro.data.synthetic import MarkovLM


class PhaseDataLoader:
    """Iterates (phase, step, batch) over a plan.

    The token stream is indexed by absolute sequence number, so a cosine
    run (constant B) and a Seesaw run (ramped B) consume identical
    sequences in identical order at equal token counts.
    """

    def __init__(self, source: MarkovLM, plan: SeesawPlan, seq_len: int,
                 mesh=None, multi_pod: bool = False):
        self.source = source
        self.plan = plan
        self.seq_len = seq_len
        self.mesh = mesh
        self.multi_pod = multi_pod

    def _shard(self, batch: Dict[str, np.ndarray]):
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        axes = ("pod", "data") if self.multi_pod else ("data",)
        out = {}
        for k, v in batch.items():
            spec = P(axes, *([None] * (v.ndim - 1)))
            out[k] = jax.device_put(
                v, NamedSharding(self.mesh, spec))
        return out

    def __iter__(self) -> Iterator[Tuple[Any, int, Dict[str, Any]]]:
        seq_cursor = 0        # absolute sequence index into the stream
        steps = self.plan.steps_per_phase(self.seq_len)
        for phase, n_steps in zip(self.plan.phases, steps):
            for s in range(n_steps):
                batch = self.source.sample(seq_cursor, phase.batch_size,
                                           self.seq_len)
                seq_cursor += phase.batch_size
                yield phase, s, self._shard(batch)
