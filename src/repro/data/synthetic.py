"""Deterministic synthetic data sources.

MarkovLM — a sparse bigram language with Zipf-weighted transitions: a
model must actually learn the transition table, so losses decrease
smoothly toward the chain's conditional entropy; reproducible per
(seed, step) so two schedulers see identical data order at equal token
counts (the paper's equal-FLOPs comparisons need this).

LinearRegressionSampler — the Section-5 distribution
x~N(0,H), y = ⟨w*,x⟩ + N(0,σ²), sampled in the eigenbasis.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


class MarkovLM:
    def __init__(self, vocab_size: int = 2048, branching: int = 16,
                 seed: int = 0, zipf_a: float = 1.2):
        self.vocab_size = vocab_size
        self.branching = branching
        self.seed = seed
        rng = np.random.default_rng(seed)
        self.table = np.stack([
            rng.choice(vocab_size, size=branching, replace=False)
            for _ in range(vocab_size)
        ])                                           # (V, K)
        w = (np.arange(1, branching + 1, dtype=np.float64)) ** (-zipf_a)
        rows = []
        for _ in range(vocab_size):
            rows.append(rng.permutation(w))
        probs = np.stack(rows)
        probs /= probs.sum(axis=1, keepdims=True)
        self.probs = probs
        self.cdf = np.cumsum(probs, axis=1)          # (V, K)

    def conditional_entropy(self) -> float:
        """H(next|cur) under the uniform state distribution ≈ loss floor."""
        p = self.probs
        return float(-(p * np.log(p)).sum(axis=1).mean())

    @staticmethod
    def _mix(x: np.ndarray) -> np.ndarray:
        """splitmix64 finalizer — counter-based, vectorized."""
        x = (x + np.uint64(0x9E3779B97F4A7C15))
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> np.uint64(31))

    def _uniform(self, idx: np.ndarray, t: np.ndarray) -> np.ndarray:
        """U(0,1) keyed by (seed, absolute sequence index, position) —
        sequence #i is identical no matter which batch it lands in, so
        ramped and constant-batch runs see the same stream."""
        with np.errstate(over="ignore"):
            key = (np.uint64(self.seed) * np.uint64(0xD1342543DE82EF95)
                   ^ self._mix(idx.astype(np.uint64))[:, None]
                   ^ self._mix(t.astype(np.uint64)
                               + np.uint64(0x5851F42D4C957F2D))[None, :])
            h = self._mix(key)
        return (h >> np.uint64(11)).astype(np.float64) * 2.0 ** -53

    def sample(self, start: int, batch: int, seq_len: int
               ) -> Dict[str, np.ndarray]:
        """Sequences [start, start+batch) of the absolute stream.
        Tokens (batch, seq_len+1) split into inputs/labels."""
        idx = np.arange(start, start + batch, dtype=np.uint64)
        u = self._uniform(idx, np.arange(seq_len, dtype=np.uint64))
        state = (self._mix(idx ^ np.uint64(self.seed * 7919 + 13))
                 % np.uint64(self.vocab_size)).astype(np.int64)
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = state
        for t in range(seq_len):
            j = (self.cdf[state] < u[:, t:t + 1]).sum(axis=1)
            state = self.table[state, np.minimum(j, self.branching - 1)]
            toks[:, t + 1] = state
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class LinearRegressionSampler:
    def __init__(self, lam: np.ndarray, sigma2: float = 1.0,
                 seed: int = 0, w_star: Optional[np.ndarray] = None):
        self.lam = np.asarray(lam, np.float64)
        self.sigma = float(np.sqrt(sigma2))
        self.seed = seed
        d = self.lam.shape[0]
        self.w_star = (np.zeros(d) if w_star is None
                       else np.asarray(w_star, np.float64))

    def sample(self, step: int, batch: int
               ) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        x = rng.normal(size=(batch, self.lam.shape[0])) \
            * np.sqrt(self.lam)[None, :]
        y = x @ self.w_star + self.sigma * rng.normal(size=batch)
        return x.astype(np.float32), y.astype(np.float32)

    def risk(self, w: np.ndarray) -> float:
        """Population risk ½E(⟨w,x⟩−y)² (excess + σ²/2)."""
        d = w - self.w_star
        return 0.5 * float(np.sum(self.lam * d * d) + self.sigma ** 2)
