"""The phase execution engine — the single train-step stack.

Every entry point (``Trainer``, ``launch.train``, ``launch.dryrun`` via
``launch.steps``, benchmarks, examples) drives the same step builder,
so there is exactly one ``value_and_grad`` call site for training in
the repo.  The engine owns four layers:

1. ``make_grad_step`` — the inner step ``(params, opt_state, batch, lr)
   → (params, opt_state, metrics)``.  Gradient accumulation is a
   ``lax.scan`` over microbatches: the trace size is constant at any
   accumulation count, so the batch ramp changes a scan trip count,
   never the program size.
2. ``plan_lr_fn`` — the token-indexed LR schedule as a traced device
   function of ``tokens_seen``.  Cosine (continuous) and
   step/seesaw/constant (piecewise) share one code path inside the
   jitted step; no host LR computation happens per step.
3. ``make_fused_step`` — K-step fused dispatch: ``lax.scan`` over a
   stacked chunk of K batches per host round-trip.  The carry is an
   exact int32 step counter (the host keeps ``tokens_seen`` as a
   Python int), ``n_valid`` masks the padded tail of a short chunk so
   one executable serves every chunk of a batch size, and metrics come
   back stacked ``(K,)`` on device, only transferred at ``log_every``
   boundaries (the caller decides when to ``device_get``).
4. ``PhaseEngine`` — per-(batch_size, micro, K) compile cache of
   donated, ``NamedSharding``-annotated jitted steps.  A batch-size
   change is one retrace; K=1 is the eager path and runs through the
   identical scan body, so fused and eager trajectories match bitwise.

Sharding-tree helpers (``param_structs`` / ``opt_structs`` /
``opt_state_specs`` / ``named_shardings``) live here too and are
re-exported by ``launch.steps`` for the dry-run.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, OptimizerConfig, RunConfig
from repro.core import schedules as S
from repro.core.seesaw import SeesawPlan
from repro.models import registry as R
from repro.optim import optimizers as O

Params = Any


# --------------------------------------------------------------------- #
# sharding-tree helpers (shared with launch.steps)
# --------------------------------------------------------------------- #

def named_shardings(mesh, tree):
    """PartitionSpec tree → NamedSharding tree on ``mesh``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def param_structs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: R.init_params(jax.random.PRNGKey(0), cfg))


def opt_structs(cfg: ModelConfig, params_struct, kind: str = "adamw"):
    opt = O.from_config(OptimizerConfig(kind=kind))
    return opt, jax.eval_shape(opt.init, params_struct)


def opt_state_specs(param_spec_tree, opt_state_struct):
    """Mirror param specs onto the m/v/mu slots; scalars replicated."""
    out = {}
    for k in opt_state_struct:
        if k in ("m", "v", "mu"):
            out[k] = param_spec_tree
        else:
            out[k] = P()
    return out


# --------------------------------------------------------------------- #
# 1. the single grad step
# --------------------------------------------------------------------- #

def make_grad_step(cfg: ModelConfig, optimizer: O.Optimizer, *,
                   micro_batches: int = 1, z_loss: float = 0.0,
                   dtype=jnp.bfloat16, remat: bool = True,
                   multi_pod: bool = False, **loss_kw) -> Callable:
    """The one training step builder: ``step(params, opt_state, batch,
    lr) → (params, opt_state, metrics)``.  jit-able; batch shapes decide
    the compile cache key.  Extra ``loss_kw`` (block_skip, seq_shard,
    remat_policy, …) forward to the family loss function."""

    def loss_of(params, batch):
        return R.loss_fn(params, cfg, batch, z_loss=z_loss, dtype=dtype,
                         remat=remat, multi_pod=multi_pod, **loss_kw)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def step(params, opt_state, batch, lr):
        if micro_batches > 1:
            def split(x):
                b = x.shape[0] // micro_batches
                return x.reshape(micro_batches, b, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def accum(carry, mb):
                (l_aux, g) = grad_fn(params, mb)
                gacc = jax.tree.map(jnp.add, carry, g)
                l, aux = l_aux
                return gacc, dict(aux, loss=l)

            gacc, metrics = jax.lax.scan(
                accum, jax.tree.map(jnp.zeros_like, params), micro)
            grads = jax.tree.map(lambda g: g / micro_batches, gacc)
            metrics = jax.tree.map(jnp.mean, metrics)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        new_params, new_opt = optimizer.update(grads, opt_state, params,
                                               lr)
        metrics = {k: jnp.asarray(v, jnp.float32)
                   for k, v in metrics.items()}
        metrics["grad_norm"] = O._global_norm(grads)
        return new_params, new_opt, metrics

    return step


# --------------------------------------------------------------------- #
# 2. device-side token-indexed LR
# --------------------------------------------------------------------- #

def plan_lr_fn(plan: SeesawPlan,
               seq_len: Optional[int] = None) -> Callable:
    """The plan's LR curve as a traced function ``lr(tokens_seen,
    step=None)``.  Cosine plans get the continuous quarter-cosine
    (Lemma 1); every piecewise kind gets :func:`schedules.piecewise_lr`
    over the phase table.

    With ``seq_len`` the cut thresholds are the *realized* phase starts
    (step-quantized via ``steps_per_phase``), not the ideal token cut
    points — the loader switches batch size on step boundaries, and the
    LR cut must land on the same step so each step trains with its
    phase's (lr, batch) pair.  The realized ends are accumulated in
    exact integer arithmetic and the cumulative *step* boundaries are
    handed to ``piecewise_lr`` too, so a jitted step that knows its
    global step index selects the cut by exact int32 compare (immune to
    f32 rounding past 2^24 tokens)."""
    if plan.kind == "cosine":
        return S.quarter_cosine_lr(plan.base_lr, plan.total_tokens,
                                   plan.warmup_tokens)
    if seq_len:
        ends, step_ends, tok, n_cum = [], [], 0, 0
        for p, n in zip(plan.phases, plan.steps_per_phase(seq_len)):
            tok += n * p.batch_size * seq_len
            n_cum += n
            ends.append(tok)
            step_ends.append(n_cum)
    else:
        ends = [p.end_tokens for p in plan.phases]
        step_ends = None
    return S.piecewise_lr(plan.base_lr, plan.warmup_tokens, ends,
                          [p.lr_scale for p in plan.phases],
                          phase_end_steps=step_ends)


# --------------------------------------------------------------------- #
# 3. K-step fused dispatch
# --------------------------------------------------------------------- #

def make_fused_step(grad_step: Callable, lr_fn: Callable,
                    tokens_per_step: float, *,
                    ema_decay: Optional[float] = None,
                    n_lr_args: int = 0) -> Callable:
    """Wrap a grad step into ``fused(params, opt_state, tokens_seen,
    step0, n_valid, batches)`` where ``batches`` has a leading K dim.
    One host dispatch covers up to K optimizer steps; metrics (plus the
    per-step ``lr``) return stacked ``(K,)``.

    Two extensions serve the adaptive-Seesaw path (both default off,
    leaving the signature and compiled program of prescheduled runs
    untouched):

    - ``ema_decay`` — carry a loss EMA through the scan:  the signature
      becomes ``fused(params, opt_state, tokens_seen, step0, n_valid,
      ema0, batches, *lr_args)`` returning ``(params, opt_state,
      metrics, ema)``.  The EMA is one f32 scalar updated per *valid*
      step (``ema ← d·ema + (1−d)·loss``; padded tail steps leave it
      unchanged), so the plateau controller reads one smoothed scalar
      per chunk with zero per-step host transfers.  A negative ``ema0``
      is the "unseeded" sentinel: the first valid loss seeds it.
    - ``n_lr_args`` — the LR schedule's phase table as that many extra
      traced arguments (see :func:`schedules.adaptive_piecewise_lr`):
      extending the plan at a cut changes argument *values* only, so
      the per-batch-size executables compiled before the cut stay
      valid.

    The scan carry is an exact int32 step counter, not an f32 token
    accumulator: step i's token count is ``tokens_seen + i *
    tokens_per_step`` with the offset computed in int32 (exact for any
    chunk under 2^31 tokens; the old ``tok + tps`` f32 carry drifted
    once a chunk crossed 2^24 tokens).  The exact running total lives
    on the host as a Python int; ``tokens_seen`` arrives here already
    rounded once to f32, and the device LR receives the global step
    index ``step0 + i`` so piecewise cuts are selected by integer
    compare (see :func:`plan_lr_fn`).

    ``n_valid`` masks the tail of a padded chunk: steps with
    ``i >= n_valid`` take a ``lax.cond`` branch that returns params and
    opt state untouched (and zero metrics), so a merged chunk stream
    can pad every tail chunk up to K and reuse the single compiled
    executable — no remainder programs — without perturbing training.
    ``n_valid`` is a traced scalar, so varying it never recompiles."""
    tps = jnp.int32(int(tokens_per_step))
    takes_step = _takes_step(lr_fn)

    def _make_real(params, opt_state, batches):
        def real(operand):
            params, opt_state, batch, lr = operand
            p, o, m = grad_step(params, opt_state, batch, lr)
            return p, o, dict(m, lr=jnp.asarray(lr, jnp.float32))

        # metrics pytree structure for the skip branch, from one
        # abstract eval of the real step (scan traces the body once,
        # so this costs a single extra abstract pass per compile)
        m_struct = jax.eval_shape(
            real, (params, opt_state,
                   jax.tree.map(lambda x: x[0], batches),
                   jnp.float32(0)))[2]

        def skip(operand):
            params, opt_state, _, _ = operand
            zeros = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), m_struct)
            return params, opt_state, zeros

        return real, skip

    def _step_lr(tokens_seen, step0, i, lr_args):
        tok = (jnp.asarray(tokens_seen, jnp.float32)
               + (i * tps).astype(jnp.float32))
        # a negative step0 means "step index unknown": keep the
        # sentinel for EVERY step of the chunk (step0 + i would
        # turn non-negative from i=1 on and silently select the
        # wrong piecewise phase)
        stepi = jnp.where(step0 < 0, jnp.int32(-1), step0 + i)
        if lr_args:
            return lr_fn(tok, stepi, *lr_args)
        return lr_fn(tok, stepi) if takes_step else lr_fn(tok)

    if ema_decay is None:
        def fused(params, opt_state, tokens_seen, step0, n_valid,
                  batches, *lr_args):
            real, skip = _make_real(params, opt_state, batches)

            def body(carry, batch):
                params, opt_state, i = carry
                lr = _step_lr(tokens_seen, step0, i, lr_args)
                params, opt_state, metrics = jax.lax.cond(
                    i < n_valid, real, skip,
                    (params, opt_state, batch, lr))
                return (params, opt_state, i + jnp.int32(1)), metrics

            carry = (params, opt_state, jnp.int32(0))
            (params, opt_state, _), metrics = jax.lax.scan(body, carry,
                                                           batches)
            return params, opt_state, metrics

        return fused

    decay = jnp.float32(ema_decay)

    def fused_ema(params, opt_state, tokens_seen, step0, n_valid,
                  ema0, batches, *lr_args):
        real, skip = _make_real(params, opt_state, batches)

        def body(carry, batch):
            params, opt_state, i, ema = carry
            lr = _step_lr(tokens_seen, step0, i, lr_args)
            params, opt_state, metrics = jax.lax.cond(
                i < n_valid, real, skip,
                (params, opt_state, batch, lr))
            loss = jnp.asarray(metrics["loss"], jnp.float32)
            # ema0 < 0 = unseeded: the first valid loss seeds the EMA;
            # padded tail steps (masked loss = 0) leave it unchanged
            upd = jnp.where(ema < 0, loss,
                            decay * ema + (1.0 - decay) * loss)
            ema = jnp.where(i < n_valid, upd, ema)
            return (params, opt_state, i + jnp.int32(1), ema), metrics

        carry = (params, opt_state, jnp.int32(0),
                 jnp.asarray(ema0, jnp.float32))
        (params, opt_state, _, ema), metrics = jax.lax.scan(
            body, carry, batches)
        return params, opt_state, metrics, ema

    return fused_ema


def _takes_step(lr_fn: Callable) -> bool:
    """Whether ``lr_fn`` accepts the global step index as a second
    argument (every :mod:`repro.core.schedules` curve does; ad-hoc
    token-only callables keep working)."""
    try:
        import inspect
        sig = inspect.signature(lr_fn)
    except (TypeError, ValueError):
        return False
    if len(sig.parameters) >= 2:
        return True
    return any(p.kind is inspect.Parameter.VAR_POSITIONAL
               for p in sig.parameters.values())


# --------------------------------------------------------------------- #
# 4. the engine
# --------------------------------------------------------------------- #

class PhaseEngine:
    """Compile cache + dispatcher for one run.

    Keys are ``(batch_size, micro_batches, K)``; each entry is one
    donated jitted fused step, sharding-annotated when a mesh is given.
    The batch ramp walks batch sizes, so a plan fed by the loader's
    merged, tail-padded chunk stream compiles exactly one program per
    *distinct* batch size — remainder chunks reuse the K-sized program
    with ``n_valid`` masking the padded tail.

    ``adaptive-seesaw`` plans get three extra behaviours: the fused
    step carries a device loss EMA (returned as a fourth output of
    :meth:`run_chunk`), the LR phase table is passed as runtime
    arguments (:meth:`_lr_tables`) so :meth:`update_plan` can swap in
    an extended plan without invalidating any cached executable, and
    :meth:`prewarm_async` AOT-compiles the next ramp stage's program in
    a background thread so a fired cut costs one background compile
    instead of a stall at the next batch size's first chunk.
    """

    def __init__(self, cfg: RunConfig, optimizer: O.Optimizer,
                 plan: SeesawPlan, *, mesh=None, multi_pod: bool = False,
                 max_device_batch: Optional[int] = None):
        self.cfg = cfg
        # run-level --kernel-backend override folded into the model
        # config here, so every compiled step (and its param/opt-state
        # spec derivation) sees one consistent backend
        self.model = cfg.resolved_model()
        self.optimizer = optimizer
        self.plan = plan
        self.mesh = mesh
        self.multi_pod = multi_pod
        self.max_device_batch = max_device_batch
        self.adaptive = plan.kind == "adaptive-seesaw"
        if self.adaptive:
            sch = cfg.schedule
            self.ema_decay = float(
                getattr(sch, "ema_decay", 0.98) or 0.98)
            # fixed-width runtime LR tables: one slot per phase the
            # controller can ever create (n_cuts cuts ⇒ n_cuts + 1
            # phases) plus one slack slot — fixed width means a cut
            # never changes an argument shape, hence never recompiles
            self._table_width = max(int(sch.n_cuts) + 2, 2)
            self.lr_fn = S.adaptive_piecewise_lr(plan.base_lr,
                                                 plan.warmup_tokens)
        else:
            self.lr_fn = plan_lr_fn(plan, cfg.seq_len)
        self.dtype = (jnp.bfloat16 if cfg.dtype == "bfloat16"
                      else jnp.float32)
        self._cache: Dict[Tuple[int, int, int], Callable] = {}
        self._prewarm: Dict[Tuple[int, int, int],
                            threading.Thread] = {}

    # -- mesh geometry -------------------------------------------------- #
    def n_data_devices(self) -> int:
        from repro.launch.mesh import data_parallel_size
        return data_parallel_size(self.mesh)

    def micro_batches(self, batch_size: int) -> int:
        """Accumulation count for a global batch.  The microbatch is a
        slice of the *global* batch, so it must both divide the global
        batch and still split evenly across the data devices — checking
        only ``batch_size % micro`` (the old trainer bug) can pick a
        micro whose per-device share is fractional.

        When NO accumulation count satisfies both divisibility
        constraints (e.g. a global batch not divisible by the data
        device count), raise instead of silently returning
        ``micro == batch_size`` — that fallthrough had exactly the
        fractional per-device share this method exists to rule out."""
        if not self.max_device_batch:
            return 1
        n_dev = max(self.n_data_devices(), 1)
        per_dev = batch_size // n_dev
        micro = max(-(-per_dev // self.max_device_batch), 1)
        while micro <= batch_size:
            if (batch_size % micro == 0
                    and (batch_size // micro) % n_dev == 0):
                return micro
            micro += 1
        raise ValueError(
            f"no gradient-accumulation count splits global batch "
            f"{batch_size} into microbatches of <= "
            f"{self.max_device_batch} rows per device across {n_dev} "
            f"data devices: every divisor of {batch_size} leaves a "
            f"per-device share that is fractional — use a batch size "
            f"divisible by {n_dev}")

    # -- adaptive runtime LR tables ------------------------------------- #
    def _lr_tables(self):
        """The adaptive schedule's phase table as runtime arrays:
        realized cumulative cut steps (i32), cut token boundaries (f32)
        and per-phase LR scales (f32), each padded to the fixed
        ``_table_width`` — ``INT32_MAX`` / ``+inf`` cut slots never
        match, and the scale pad repeats the last phase.  Fixed width
        means extending the plan changes argument *values* only; no
        cached executable is invalidated by a cut.

        Cut boundaries are the *realized* (step-quantized) phase
        starts, accumulated in exact integer arithmetic — the same
        convention as :func:`plan_lr_fn` — so the LR cut lands on the
        step where the loader actually switches batch size."""
        plan, seq = self.plan, self.cfg.seq_len
        W = self._table_width
        if len(plan.phases) > W:
            raise ValueError(
                f"plan has {len(plan.phases)} phases but the runtime "
                f"LR table was sized for {W} (schedule.n_cuts + 2) — "
                f"raise n_cuts to allow more adaptive cuts")
        cut_steps, cut_toks, tok, n_cum = [], [], 0, 0
        for p, n in zip(plan.phases[:-1],
                        plan.steps_per_phase(seq)[:-1]):
            tok += n * p.batch_size * seq
            n_cum += n
            cut_steps.append(n_cum)
            cut_toks.append(float(tok))
        scales = [p.lr_scale for p in plan.phases]
        pad = W - len(cut_steps)
        cut_steps += [2 ** 31 - 1] * pad
        cut_toks += [float("inf")] * pad
        scales += [scales[-1]] * (W - len(scales))
        return (jnp.asarray(cut_steps, jnp.int32),
                jnp.asarray(cut_toks, jnp.float32),
                jnp.asarray(scales, jnp.float32))

    def update_plan(self, plan: SeesawPlan) -> None:
        """Swap in an extended plan after an adaptive cut.  Only valid
        for the adaptive kind — prescheduled engines bake their LR
        table into the compiled program, so swapping their plan would
        silently train on stale cuts."""
        if not self.adaptive:
            raise ValueError(
                "update_plan is only valid for adaptive-seesaw "
                "engines; prescheduled plans are baked into the "
                "compiled step")
        self.plan = plan
        self._lr_tables()    # fail fast on table-width overflow

    def host_lr(self, tokens: float,
                step: Optional[int] = None) -> float:
        """The schedule's LR at a host-known position (logging /
        probes) — hides the adaptive runtime-table calling convention
        from callers."""
        if self.adaptive:
            return float(self.lr_fn(
                float(tokens), -1 if step is None else int(step),
                *self._lr_tables()))
        return float(self.lr_fn(float(tokens)))

    # -- sharding specs ------------------------------------------------- #
    def _batch_axes(self):
        return ("pod", "data") if self.multi_pod else ("data",)

    def _state_specs(self):
        """(param PartitionSpec tree, opt-state PartitionSpec tree)."""
        pspec = R.param_specs(self.model, self.multi_pod)
        pstruct = param_structs(self.model)
        ostruct = jax.eval_shape(self.optimizer.init, pstruct)
        return pspec, opt_state_specs(pspec, ostruct)

    def state_shardings(self):
        """``(param NamedSharding tree, opt-state NamedSharding tree)``
        of the run's train state on this engine's mesh — what the
        checkpoint layer needs to restore each process's addressable
        shards only (``checkpoint.restore(..., shardings=...)``).
        ``None`` without a mesh (single-device placement) — or with a
        duck-typed mesh stand-in (only real meshes can build
        ``NamedSharding``s; geometry helpers accept anything with a
        ``.shape``)."""
        if not isinstance(self.mesh, jax.sharding.Mesh):
            return None
        pspec, ospec = self._state_specs()
        return (named_shardings(self.mesh, pspec),
                named_shardings(self.mesh, ospec))

    def _shardings(self, stacked_batch):
        """(in_shardings, out_shardings) for the fused step.  Inputs:
        (params, opt_state, tokens, step0, n_valid, batches) with the
        three control scalars replicated.  Outputs pin params/opt state
        to the same specs as the inputs — without the constraint XLA
        is free to return a donated output with whatever sharding
        propagation inferred, and the *next* compiled program (a new
        batch size in the ramp) would then reject the arg as
        mismatched mid-run."""
        pspec, ospec = self._state_specs()
        axes = self._batch_axes()

        def bspec(x):
            # leading K dim replicated, batch dim sharded over data axes
            return P(None, axes, *([None] * (x.ndim - 2)))

        bspecs = jax.tree.map(bspec, stacked_batch)
        if self.adaptive:
            # extra replicated leaves: ema0 before the batches, the
            # three LR-table arrays after, and the EMA scalar output
            in_sh = named_shardings(
                self.mesh, (pspec, ospec, P(), P(), P(), P(), bspecs,
                            P(), P(), P()))
            out_sh = (named_shardings(self.mesh, pspec),
                      named_shardings(self.mesh, ospec),
                      NamedSharding(self.mesh, P()),  # stacked metrics
                      NamedSharding(self.mesh, P()))  # loss EMA
        else:
            in_sh = named_shardings(
                self.mesh, (pspec, ospec, P(), P(), P(), bspecs))
            out_sh = (named_shardings(self.mesh, pspec),
                      named_shardings(self.mesh, ospec),
                      NamedSharding(self.mesh, P()))  # stacked metrics
        return in_sh, out_sh

    # -- compile cache -------------------------------------------------- #
    def _build_jit(self, batch_size: int, micro: int,
                   batch_structs=None) -> Callable:
        """The jitted (not yet traced) fused step for a batch size —
        shared by the lazy :meth:`compiled_step` path and the AOT
        :meth:`prewarm_async` path so both produce the identical
        program.  ``batch_structs`` (arrays or ShapeDtypeStructs with
        the stacked ``(K, B, ...)`` shapes) is only needed to derive
        shardings on a mesh."""
        grad = make_grad_step(self.model, self.optimizer,
                              micro_batches=micro,
                              z_loss=self.cfg.z_loss,
                              dtype=self.dtype,
                              remat=self.cfg.remat,
                              multi_pod=self.multi_pod)
        fused = make_fused_step(
            grad, self.lr_fn, batch_size * self.cfg.seq_len,
            ema_decay=self.ema_decay if self.adaptive else None,
            n_lr_args=3 if self.adaptive else 0)
        kw = {}
        if self.mesh is not None and batch_structs is not None:
            kw["in_shardings"], kw["out_shardings"] = \
                self._shardings(batch_structs)
        return jax.jit(fused, donate_argnums=(0, 1), **kw)

    def compiled_step(self, batch_size: int, k: int,
                      stacked_batch=None) -> Callable:
        micro = self.micro_batches(batch_size)
        key = (batch_size, micro, k)
        if key not in self._cache and key in self._prewarm:
            # a background AOT compile for this key is in flight —
            # join it rather than compiling the same program twice
            self._prewarm.pop(key).join()
        if key not in self._cache:
            self._cache[key] = self._build_jit(batch_size, micro,
                                               stacked_batch)
        return self._cache[key]

    def _arg_structs(self, batch_size: int, k: int, stacked_batch):
        """ShapeDtypeStructs of one fused-step call at ``(batch_size,
        k)`` — the AOT lowering inputs for :meth:`prewarm_async`.  The
        batch structs reshape the *current* chunk's per-example shapes
        to the target batch size, so prewarm needs no example data."""
        pstruct = param_structs(self.model)
        ostruct = jax.eval_shape(self.optimizer.init, pstruct)
        bstruct = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                (k, batch_size) + tuple(x.shape[2:]), x.dtype),
            stacked_batch)

        def scal(dt):
            return jax.ShapeDtypeStruct((), dt)

        args = [pstruct, ostruct, scal(jnp.float32), scal(jnp.int32),
                scal(jnp.int32)]
        if self.adaptive:
            args.append(scal(jnp.float32))       # ema0
        args.append(bstruct)
        if self.adaptive:
            W = self._table_width
            args += [jax.ShapeDtypeStruct((W,), jnp.int32),
                     jax.ShapeDtypeStruct((W,), jnp.float32),
                     jax.ShapeDtypeStruct((W,), jnp.float32)]
        return tuple(args)

    def prewarm_async(self, batch_size: int, k: int, stacked_batch):
        """AOT-compile the fused step for a *future* batch size in a
        background thread (``jit(...).lower(structs).compile()``), so
        an adaptive cut's ramp stage is already compiled when its first
        chunk arrives — the cut costs one background compile instead of
        a dispatch stall.  ``stacked_batch`` is the current chunk,
        used only for its per-example shapes/dtypes.

        Returns the started thread, or ``None`` when the program is
        already cached or warming.  :meth:`compiled_step` joins an
        in-flight thread for its key before falling back to a lazy
        compile, so racing a prewarm never compiles twice.  A failed
        background compile (e.g. an AOT-unsupported backend) degrades
        to the lazy jit path at first dispatch."""
        micro = self.micro_batches(batch_size)
        key = (batch_size, micro, k)
        if key in self._cache or key in self._prewarm:
            return None
        structs = self._arg_structs(batch_size, k, stacked_batch)
        bstruct = structs[6 if self.adaptive else 5]
        jitted = self._build_jit(batch_size, micro, bstruct)

        def work():
            try:
                self._cache[key] = jitted.lower(*structs).compile()
            except Exception:
                self._cache.setdefault(key, jitted)

        t = threading.Thread(target=work, daemon=True,
                             name=f"prewarm-b{batch_size}")
        t.start()
        self._prewarm[key] = t
        return t

    # -- checkpointing -------------------------------------------------- #
    def make_checkpoint_manager(self, **kw):
        """An async :class:`repro.train.checkpoint.CheckpointManager`
        bound to this engine's plan and seq_len, so its saves carry the
        same phase metadata as the trainer's sync path.  ``kw`` passes
        through (``chunk_bytes``, ``commit_timeout``)."""
        from repro.train.checkpoint import CheckpointManager
        return CheckpointManager(plan=self.plan,
                                 seq_len=self.cfg.seq_len, **kw)

    # -- dispatch ------------------------------------------------------- #
    def run_chunk(self, params, opt_state, tokens_seen,
                  stacked_batch, n_valid: Optional[int] = None,
                  step: Optional[int] = None, loss_ema=None):
        """One host round-trip: up to K fused optimizer steps.  Returns
        (params, opt_state, stacked device metrics) without forcing a
        transfer — the caller flushes metrics at log boundaries.  An
        adaptive engine returns a fourth element: the device loss EMA
        after the chunk (a scalar DeviceArray; one ``device_get`` per
        chunk is the controller's entire host traffic).

        ``tokens_seen`` is the host's exact integer token count (a
        float on a step boundary also works); it is rounded once to
        f32 here.  ``n_valid`` (default: all K) is the number of
        leading real steps in a tail-padded chunk — metric rows past it
        are zeros and must be discarded.  ``step`` is the global step
        index of the chunk's first step; when given, piecewise LR cuts
        are selected by exact integer compare on device.  ``loss_ema``
        (adaptive only) is the EMA carried from the previous chunk;
        ``None`` means unseeded — the first valid loss seeds it."""
        leaves = jax.tree.leaves(stacked_batch)
        k, batch_size = leaves[0].shape[0], leaves[0].shape[1]
        if n_valid is None:
            n_valid = k
        if k * batch_size * self.cfg.seq_len >= 2 ** 31:
            raise ValueError(
                f"chunk of {k}x{batch_size}x{self.cfg.seq_len} tokens "
                f"overflows the int32 on-device token offset — lower "
                f"fuse_steps")
        fn = self.compiled_step(batch_size, k, stacked_batch)
        scalars = (jnp.float32(float(tokens_seen)),
                   jnp.int32(-1 if step is None else int(step)),
                   jnp.int32(int(n_valid)))
        if self.adaptive:
            ema0 = jnp.float32(
                -1.0 if loss_ema is None else float(loss_ema))
            return fn(params, opt_state, *scalars, ema0,
                      stacked_batch, *self._lr_tables())
        return fn(params, opt_state, *scalars, stacked_batch)
