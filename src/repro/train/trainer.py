"""The Seesaw training runtime.

The batch ramp is a first-class feature: the trainer walks the plan's
phases, keeps a compiled train-step per distinct global batch size
(shape change ⇒ one retrace, then cached), carries params/optimizer
state across the boundary untouched, and keeps the LR curve token-
indexed so cosine (continuous) and seesaw/step (piecewise) schedulers
share one code path.

Gradient accumulation: if a phase's global batch exceeds
``max_device_batch``, the step scans microbatches and averages grads —
the ramp then changes accumulation count, not the jitted shape.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.core import schedules as S
from repro.core.seesaw import SeesawPlan, build_plan
from repro.models import registry as R
from repro.optim import optimizers as O

Params = Any


@dataclass
class TrainState:
    params: Params
    opt_state: Params
    step: int = 0
    tokens_seen: float = 0.0


def make_train_step(cfg: RunConfig, optimizer: O.Optimizer, *,
                    multi_pod: bool = False,
                    micro_batches: int = 1) -> Callable:
    """Returns step(params, opt_state, batch, lr) → (params, opt_state,
    metrics).  jit-able; batch shapes decide the compile cache key."""
    mcfg = cfg.model
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def loss_of(params, batch):
        return R.loss_fn(params, mcfg, batch, z_loss=cfg.z_loss,
                         dtype=dtype, remat=cfg.remat,
                         multi_pod=multi_pod)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def step(params, opt_state, batch, lr):
        if micro_batches > 1:
            def split(x):
                b = x.shape[0] // micro_batches
                return x.reshape(micro_batches, b, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            gacc = jax.tree.map(jnp.zeros_like, params)
            loss_acc = 0.0
            aux = None
            for i in range(micro_batches):
                mb = jax.tree.map(lambda x, i=i: x[i], micro)
                (l, aux), g = grad_fn(params, mb)
                gacc = jax.tree.map(jnp.add, gacc, g)
                loss_acc = loss_acc + l
            grads = jax.tree.map(lambda g: g / micro_batches, gacc)
            loss = loss_acc / micro_batches
            metrics = dict(aux)
            metrics["loss"] = loss
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        new_params, new_opt = optimizer.update(grads, opt_state, params,
                                               lr)
        metrics = {k: jnp.asarray(v, jnp.float32)
                   for k, v in metrics.items()}
        metrics["grad_norm"] = O._global_norm(grads)
        return new_params, new_opt, metrics

    return step


class Trainer:
    def __init__(self, cfg: RunConfig, *, mesh=None, multi_pod: bool = False,
                 max_device_batch: Optional[int] = None, seed: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.multi_pod = multi_pod
        self.max_device_batch = max_device_batch
        total = cfg.resolved_total_tokens()
        sch = cfg.schedule
        self.plan = build_plan(
            kind=sch.kind, base_lr=sch.base_lr, total_tokens=total,
            warmup_frac=sch.warmup_frac, b0=cfg.global_batch_size,
            alpha=sch.alpha,
            beta=(sch.beta if sch.kind in ("seesaw-general", "naive-ramp")
                  else None),
            n_cuts=sch.n_cuts, max_batch_size=sch.max_batch_size)
        self.optimizer = O.from_config(cfg.optimizer)
        self._cosine = S.quarter_cosine_lr(sch.base_lr, total,
                                           sch.warmup_frac * total)
        self._step_cache: Dict[Tuple, Callable] = {}
        key = jax.random.PRNGKey(cfg.seed + seed)
        params = R.init_params(key, cfg.model)
        opt_state = self.optimizer.init(params)
        self.state = TrainState(params, opt_state)
        self.history: List[Dict[str, float]] = []

    # ------------------------------------------------------------------ #
    def lr_at(self, tokens: float) -> float:
        if self.cfg.schedule.kind == "cosine":
            return float(self._cosine(tokens))
        return self.plan.lr_at(tokens)

    def _compiled_step(self, batch_size: int, micro: int) -> Callable:
        key = (batch_size, micro)
        if key not in self._step_cache:
            fn = make_train_step(self.cfg, self.optimizer,
                                 multi_pod=self.multi_pod,
                                 micro_batches=micro)
            self._step_cache[key] = jax.jit(fn, donate_argnums=(0, 1))
        return self._step_cache[key]

    def _micro(self, batch_size: int) -> int:
        if not self.max_device_batch:
            return 1
        n_dev = 1 if self.mesh is None else int(np.prod(
            [self.mesh.shape[a] for a in ("pod", "data")
             if a in self.mesh.shape])) or 1
        per_dev = batch_size // max(n_dev, 1)
        micro = -(-per_dev // self.max_device_batch)
        while batch_size % micro:
            micro += 1
        return micro

    def run(self, loader, max_steps: Optional[int] = None,
            log_cb: Optional[Callable] = None) -> List[Dict[str, float]]:
        st = self.state
        t0 = time.time()
        for phase, pstep, batch in loader:
            if max_steps is not None and st.step >= max_steps:
                break
            lr = self.lr_at(st.tokens_seen)
            micro = self._micro(phase.batch_size)
            fn = self._compiled_step(phase.batch_size, micro)
            params, opt_state, metrics = fn(
                st.params, st.opt_state, batch, jnp.asarray(lr, jnp.float32))
            st.params, st.opt_state = params, opt_state
            tok = phase.batch_size * self.cfg.seq_len
            st.tokens_seen += tok
            st.step += 1
            rec = {"step": st.step, "tokens": st.tokens_seen, "lr": lr,
                   "batch_size": phase.batch_size, "phase": phase.index,
                   "loss": float(metrics["loss"]),
                   "wall": time.time() - t0}
            for k, v in metrics.items():
                if k != "loss":
                    rec[k] = float(v)
            self.history.append(rec)
            if log_cb and (st.step % self.cfg.log_every == 0):
                log_cb(rec)
        return self.history
