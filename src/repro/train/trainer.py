"""The Seesaw training runtime, driving the phase execution engine.

The batch ramp is a first-class feature: the trainer walks the plan's
phases and lets :class:`repro.train.engine.PhaseEngine` keep one
donated, sharding-annotated compiled step per distinct global batch
size (shape change ⇒ one retrace, then cached).  Params and optimizer
state cross phase boundaries untouched.

Unlike the old eager loop, nothing schedule-related happens on host per
step: the token-indexed LR curve is evaluated inside the jitted step,
K steps are fused into one dispatch (``fuse_steps``), and metrics stay
on device until a ``log_every`` boundary forces a transfer.  Gradient
accumulation (phase batch > ``max_device_batch``) is a ``lax.scan``
over microbatches, so the ramp changes a trip count, not the trace.
The loader's chunk stream is merged across same-batch-size phases and
tail-padded to ``fuse_steps``, so a whole run compiles exactly one
fused program per distinct batch size; ``tokens_seen`` is carried as
an exact integer on the host.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.core.seesaw import build_plan
from repro.models import registry as R
from repro.optim import optimizers as O
from repro.train import checkpoint as CKPT
from repro.train import engine as E

Params = Any


@dataclass
class TrainState:
    params: Params
    opt_state: Params
    step: int = 0
    # exact integer token count — the host is the source of truth; the
    # device only ever sees a once-rounded f32 base plus an int32
    # per-chunk offset, so the carry never drifts however long the run
    tokens_seen: int = 0
    # adaptive-seesaw only: the device-accumulated loss EMA after the
    # last chunk (None = unseeded); carried into the next chunk and
    # through checkpoints so resume replays the controller bitwise
    loss_ema: Optional[float] = None


def _place_like(tree, shardings):
    """Initial state placement onto the mesh: in a multi-process run a
    process-private (single-device) array cannot feed a jitted step
    whose ``in_shardings`` span other processes, so each process
    contributes its addressable blocks of the identically-seeded host
    value and jax assembles the global array."""
    def place(x, s):
        host = np.asarray(x)
        return jax.make_array_from_callback(host.shape, s,
                                            lambda idx: host[idx])
    return jax.tree.map(place, tree, shardings)


def make_train_step(cfg: RunConfig, optimizer: O.Optimizer, *,
                    multi_pod: bool = False,
                    micro_batches: int = 1) -> Callable:
    """Compatibility wrapper over the engine's single step builder:
    step(params, opt_state, batch, lr) → (params, opt_state, metrics)."""
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return E.make_grad_step(cfg.resolved_model(), optimizer,
                            micro_batches=micro_batches,
                            z_loss=cfg.z_loss, dtype=dtype,
                            remat=cfg.remat, multi_pod=multi_pod)


class Trainer:
    def __init__(self, cfg: RunConfig, *, mesh=None, multi_pod: bool = False,
                 max_device_batch: Optional[int] = None, seed: int = 0,
                 fuse_steps: Optional[int] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.multi_pod = multi_pod
        self.max_device_batch = max_device_batch
        self.fuse_steps = max(int(fuse_steps or getattr(cfg, "fuse_steps",
                                                        1) or 1), 1)
        total = cfg.resolved_total_tokens()
        sch = cfg.schedule
        self.plan = build_plan(
            kind=sch.kind, base_lr=sch.base_lr, total_tokens=total,
            warmup_frac=sch.warmup_frac, b0=cfg.global_batch_size,
            alpha=sch.alpha,
            beta=(sch.beta if sch.kind in ("seesaw-general", "naive-ramp")
                  else None),
            n_cuts=sch.n_cuts, max_batch_size=sch.max_batch_size)
        # the adaptive plan grows at runtime; keep the single-phase
        # seed so a resume can rebuild the extended plan by replaying
        # the checkpointed cut tokens through extend_at
        self._base_plan = self.plan
        self.controller = None
        self.cut_tokens: List[int] = []
        if sch.kind == "adaptive-seesaw":
            from repro.core.adaptive import AdaptiveSeesaw
            mn = getattr(sch, "plateau_min_steps", None)
            self.controller = AdaptiveSeesaw(
                alpha=sch.alpha,
                window=int(getattr(sch, "plateau_window", 50)),
                rel_threshold=float(getattr(sch, "plateau_threshold",
                                            2e-3)),
                max_cuts=int(sch.n_cuts),
                min_steps_between=int(
                    mn if mn is not None
                    else getattr(sch, "plateau_window", 50)))
        self.optimizer = O.from_config(cfg.optimizer)
        self.engine = E.PhaseEngine(cfg, self.optimizer, self.plan,
                                    mesh=mesh, multi_pod=multi_pod,
                                    max_device_batch=max_device_batch)
        key = jax.random.PRNGKey(cfg.seed + seed)
        # resolved_model() also fail-fasts a bad --kernel-backend here
        params = R.init_params(key, cfg.resolved_model())
        opt_state = self.optimizer.init(params)
        # single-process runs skip this: jit's in_shardings place the
        # state directly, without a host round-trip of every leaf
        if jax.process_count() > 1:
            sh = self.engine.state_shardings()
            if sh is not None:
                params = _place_like(params, sh[0])
                opt_state = _place_like(opt_state, sh[1])
        self.state = TrainState(params, opt_state)
        self.history: List[Dict[str, float]] = []
        self._ckpt_manager: Optional[CKPT.CheckpointManager] = None

    # ------------------------------------------------------------------ #
    @property
    def _step_cache(self):
        return self.engine._cache

    def lr_at(self, tokens: float) -> float:
        """Host-side probe of the exact curve the jitted step evaluates
        on device (``engine.plan_lr_fn`` — piecewise cuts land on the
        realized step-quantized phase boundaries, not the plan's ideal
        token cut points).  For adaptive plans the engine supplies the
        current runtime LR tables, so this reflects every cut fired so
        far."""
        return self.engine.host_lr(tokens)

    def _micro(self, batch_size: int) -> int:
        return self.engine.micro_batches(batch_size)

    # -- checkpointing -------------------------------------------------- #
    @property
    def checkpoint_manager(self) -> "CKPT.CheckpointManager":
        """The trainer's async checkpoint writer, built lazily from the
        engine (so runs that never save pay nothing)."""
        if self._ckpt_manager is None:
            self._ckpt_manager = self.engine.make_checkpoint_manager()
        return self._ckpt_manager

    def save_checkpoint(self, path: str,
                        chunk_bytes: int = CKPT.DEFAULT_CHUNK_BYTES,
                        block: bool = True):
        """Write a sharded streaming checkpoint directory (collective
        in a multi-process run: every process writes only the shards it
        owns, in ``chunk_bytes``-bounded device→host slices).
        ``block=False`` snapshots the state on device and returns
        immediately while the :attr:`checkpoint_manager`'s writer
        thread streams it to disk."""
        extra = self._adaptive_extra()
        if not block:
            self.checkpoint_manager.request_save(
                path, self.state.params, self.state.opt_state,
                self.state.step, self.state.tokens_seen, extra)
            return
        if self._ckpt_manager is not None:
            # an in-flight async save of an older snapshot must land
            # first: generations are sequential per directory
            self._ckpt_manager.finalize()
        CKPT.save_phase_checkpoint(path, self.state.params,
                                   self.state.opt_state, self.state.step,
                                   self.state.tokens_seen, plan=self.plan,
                                   seq_len=self.cfg.seq_len, extra=extra,
                                   chunk_bytes=chunk_bytes)

    def _adaptive_extra(self) -> Optional[Dict[str, Any]]:
        """Checkpoint metadata that lets a resume replay the adaptive
        run bitwise: the controller's window state, every cut's token
        boundary (to rebuild the extended plan), and the carried loss
        EMA."""
        if self.controller is None:
            return None
        return {"adaptive": {
            "controller": self.controller.state_dict(),
            "cut_tokens": list(self.cut_tokens),
            "loss_ema": self.state.loss_ema}}

    def restore_checkpoint(self, path: str,
                           verify: bool = False) -> Dict[str, Any]:
        """Restore sharded-directory or legacy ``.npz`` checkpoints.
        With a mesh, each process reads only its addressable block of
        every array and the global state is reassembled across
        processes — no host ever holds a full replica of a sharded
        leaf.  The save-time topology need not match this run's
        (elastic resume).  ``verify=True`` checks every block's crc32
        first.

        An adaptive trainer first reads the checkpoint's metadata
        alone: the saved cut tokens rebuild the extended plan (by
        replaying :meth:`SeesawPlan.extend_at` from the single-phase
        base plan), and the controller's window state is reloaded — so
        the phase/batch validation below runs against the plan the run
        actually had at save time, and subsequent cuts re-fire at
        identical steps."""
        if self.controller is not None:
            ad = CKPT.read_meta(path).get("adaptive")
            if ad is None:
                raise ValueError(
                    f"checkpoint {path!r} carries no adaptive "
                    f"controller state — it was saved by a "
                    f"prescheduled run and cannot resume an "
                    f"adaptive-seesaw trainer")
            plan = self._base_plan
            for ct in ad["cut_tokens"]:
                plan = plan.extend_at(
                    int(ct), seq_len=self.cfg.seq_len,
                    max_batch_size=self.cfg.schedule.max_batch_size)
            self.plan = plan
            self.engine.update_plan(plan)
            if self._ckpt_manager is not None:
                self._ckpt_manager.plan = plan
            self.controller.load_state_dict(ad["controller"])
            self.cut_tokens = [int(ct) for ct in ad["cut_tokens"]]
            ema = ad.get("loss_ema")
            self.state.loss_ema = None if ema is None else float(ema)
        p, s, meta = CKPT.restore_phase_checkpoint(
            path, self.state.params, self.state.opt_state, plan=self.plan,
            seq_len=self.cfg.seq_len,
            shardings=self.engine.state_shardings(), verify=verify)
        self.state.params, self.state.opt_state = p, s
        self.state.step = int(meta["step"])
        self.state.tokens_seen = CKPT.exact_tokens(meta["tokens_seen"])
        return meta

    def close(self):
        """Join the async checkpoint writer (if any) and surface any
        writer-thread error.  Call at the end of a run that used async
        saves; idempotent."""
        if self._ckpt_manager is not None:
            self._ckpt_manager.finalize()

    # -- fused run loop ------------------------------------------------- #
    def _chunks(self, loader, max_steps):
        """Yield (head phase, stacked_batches, n): chunks with ≤
        fuse_steps real steps.  Uses the loader's double-buffered
        ``iter_chunks`` when available — those chunks always have
        leading dim fuse_steps (merged across same-batch-size phases,
        tail-padded), so truncating to a ``max_steps`` budget just
        lowers ``n`` (the engine masks the tail via ``n_valid``) and
        never creates a new chunk shape to compile.  Any plain (phase,
        step, batch) iterator works as a fallback (chunked by stacking
        on device, breaking at phase boundaries)."""
        k = self.fuse_steps
        st = self.state

        def budget():
            return None if max_steps is None else max_steps - st.step

        if hasattr(loader, "iter_chunks"):
            for phase, stacked, n in loader.iter_chunks(k):
                r = budget()
                if r is not None and r <= 0:
                    return
                if r is not None and n > r:
                    n = r
                yield phase, stacked, n
            return

        buf: List[Any] = []
        cur_phase = None
        for phase, _pstep, batch in loader:
            if max_steps is not None and st.step + len(buf) >= max_steps:
                break
            if buf and (phase.index != cur_phase.index or len(buf) == k):
                yield (cur_phase,
                       jax.tree.map(lambda *xs: jnp.stack(xs), *buf),
                       len(buf))
                buf = []
            cur_phase = phase
            buf.append(batch)
        if buf:
            r = budget()
            if r is not None and len(buf) > r:
                buf = buf[:r]
            if buf:
                yield (cur_phase,
                       jax.tree.map(lambda *xs: jnp.stack(xs), *buf),
                       len(buf))

    def _flush(self, pending, log_cb):
        """Device→host metric transfer, deferred to log boundaries.
        A merged chunk can span a phase boundary (same batch size,
        different LR scale), so each step's phase is attributed from
        its token count, not the chunk's head phase.  Metric rows past
        a chunk's ``n`` real steps are device-side padding and are
        never read."""
        le = max(self.cfg.log_every, 1)
        for base_step, base_tok, phase, wall, metrics, n in pending:
            host = jax.device_get(metrics)
            tok_per_step = phase.batch_size * self.cfg.seq_len
            for i in range(n):
                tok_start = base_tok + i * tok_per_step
                ph = self.plan.realized_phase_at(tok_start,
                                                 self.cfg.seq_len)
                rec = {"step": base_step + i + 1,
                       "tokens": base_tok + (i + 1) * tok_per_step,
                       "lr": float(host["lr"][i]),
                       "batch_size": phase.batch_size,
                       "phase": ph.index,
                       "loss": float(host["loss"][i]),
                       "wall": wall}
                for name, v in host.items():
                    if name not in ("loss", "lr"):
                        rec[name] = float(v[i])
                self.history.append(rec)
                if log_cb and rec["step"] % le == 0:
                    log_cb(rec)
        pending.clear()

    def run(self, loader, max_steps: Optional[int] = None,
            log_cb: Optional[Callable] = None, *,
            checkpoint_path: Optional[str] = None,
            save_every: Optional[int] = None,
            async_save: bool = True,
            stop_fn: Optional[Callable[[], bool]] = None
            ) -> List[Dict[str, float]]:
        """Run the fused chunk loop.  ``checkpoint_path`` +
        ``save_every`` turn on periodic saves at chunk boundaries
        (every chunk crossing a ``save_every``-step boundary) — async
        by default: the state is snapshotted on device and the writer
        thread streams it while the next chunks train; writer errors
        surface at the next chunk boundary.  ``stop_fn`` is polled at
        each chunk boundary (the preemption hook): returning True ends
        the loop cleanly with the state on an exact chunk boundary, so
        a final save/resume is bitwise-consistent.  In multi-process
        runs all of these fire at the same boundary on every process
        (the chunk stream is deterministic and save/stop decisions are
        functions of the shared step count).

        Adaptive plans add one decision per chunk boundary: the fused
        step's device loss EMA is transferred (one scalar — the
        controller's entire per-chunk host traffic) and fed to the
        plateau controller; a fired cut extends the plan, re-chunks
        the loader from this exact token boundary and restarts the
        chunk stream (the outer loop).  The cut decision runs *before*
        the boundary's save, so a checkpoint always captures the
        post-decision plan and controller — resume replays the
        remaining cuts at identical steps."""
        st = self.state
        t0 = time.time()
        le = max(self.cfg.log_every, 1)
        se = max(save_every, 1) if save_every else None
        pending: List[Tuple] = []
        stop = False
        rechunk = True
        while rechunk and not stop:
            rechunk = False
            for phase, stacked, n in self._chunks(loader, max_steps):
                if self._ckpt_manager is not None:
                    self._ckpt_manager.check()
                out = self.engine.run_chunk(
                    st.params, st.opt_state, st.tokens_seen, stacked,
                    n_valid=n, step=st.step, loss_ema=st.loss_ema)
                if self.controller is not None:
                    params, opt_state, metrics, ema = out
                    st.loss_ema = float(jax.device_get(ema))
                else:
                    params, opt_state, metrics = out
                base_step, base_tok = st.step, st.tokens_seen
                st.params, st.opt_state = params, opt_state
                st.step += n
                st.tokens_seen += n * phase.batch_size * self.cfg.seq_len
                pending.append((base_step, base_tok, phase,
                                time.time() - t0, metrics, n))
                if (self.controller is not None
                        and self.controller.observe_smoothed(
                            st.loss_ema, n)):
                    self._fire_cut(loader, stacked)
                    rechunk = True
                if st.step // le > base_step // le:
                    self._flush(pending, log_cb)
                if (se and checkpoint_path
                        and st.step // se > base_step // se):
                    self.save_checkpoint(checkpoint_path,
                                         block=not async_save)
                if stop_fn is not None and stop_fn():
                    stop = True
                if rechunk or stop:
                    break
        self._flush(pending, log_cb)
        return self.history

    def _fire_cut(self, loader, stacked) -> None:
        """Apply one adaptive cut at the current chunk boundary:
        extend the plan with a (√α LR cut, ×α batch) phase starting at
        ``tokens_seen``, validate the new ramp stage is feedable on
        this topology (fail fast at cut time, not mid-ramp), swap the
        plan into the engine / checkpoint manager / loader, and kick
        off a background AOT compile of the next batch size's fused
        step so the ramp stage starts without a dispatch stall."""
        st = self.state
        sch = self.cfg.schedule
        old_b = self.plan.phases[-1].batch_size
        new_plan = self.plan.extend_at(
            st.tokens_seen, seq_len=self.cfg.seq_len,
            max_batch_size=sch.max_batch_size)
        new_b = new_plan.phases[-1].batch_size
        if isinstance(self.mesh, jax.sharding.Mesh):
            from repro.launch.steps import validate_feeding
            validate_feeding(new_plan, self.mesh,
                             start_tokens=st.tokens_seen,
                             seq_len=self.cfg.seq_len)
        else:
            from repro.data.pipeline import validate_per_host_plan
            validate_per_host_plan(
                new_plan, getattr(loader, "_pcount", 1) or 1,
                self.engine.n_data_devices(),
                start_phase=len(new_plan.phases) - 1)
        self.plan = new_plan
        self.engine.update_plan(new_plan)
        if self._ckpt_manager is not None:
            self._ckpt_manager.plan = new_plan
        self.cut_tokens.append(int(st.tokens_seen))
        if not hasattr(loader, "rechunk"):
            raise ValueError(
                "adaptive-seesaw fired a cut but the loader cannot "
                "re-chunk mid-stream — use PhaseDataLoader (or any "
                "loader with rechunk(plan, tokens_seen))")
        loader.rechunk(new_plan, st.tokens_seen)
        if new_b != old_b:
            self.engine.prewarm_async(new_b, self.fuse_steps, stacked)
