"""Blocking batched serving over the typed KV caches: prefill + a
greedy/temperature decode loop against ``registry.decode_step``.

The prefill compile cache is bounded by prompt-length bucketing: prompts
are right-padded to a small power-of-two ladder of bucket lengths and run
through the ragged prefill (``registry.prefill_ragged``), which gathers
each request's last *real* token for the logits — so the cache is keyed
by (batch, bucket) instead of (batch, prompt-len) and two prompt lengths
in the same bucket reuse one executable.  Families without a ragged
prefill (ring-cache sliding windows, SSM, hybrid, enc-dec) keep the
legacy exact-length path.

This dense ``Server`` is the oracle the paged continuous-batching engine
(``repro.serving.ServingEngine``) is pinned against — same params, same
prompts must yield identical greedy tokens.  For new code it is also
deprecated in that engine's favor: ``generate()`` blocks the whole batch
on its slowest request and pads every prompt to a shared length, where
``ServingEngine.submit()/step()/drain()`` streams each request
independently.  The old ``generate(tokens, n_new)`` signature keeps
working (with a ``DeprecationWarning``) for callers that want the
simple blocking contract — including families the engine cannot serve.
"""
from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry as R
from repro.serving import DenseKVCache, pow2_buckets


def _bucketed_prefill(params, tokens, lengths, prefix_emb, *, cfg,
                      cache_len_cap, dtype):
    """Ragged prefill + dense-cache assembly: pad the raw per-layer K/V
    out to the cache cap.  Rows beyond ``lengths`` hold padding junk the
    decode attention masks via ``kv_len`` — exactly like the zero rows
    the legacy path padded in."""
    logits, k, v = R.prefill_ragged(params, cfg, tokens, lengths,
                                    prefix_emb=prefix_emb, dtype=dtype)
    pad = cache_len_cap - k.shape[2]
    cfgp = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
    data = {"k": jnp.pad(k, cfgp), "v": jnp.pad(v, cfgp)}
    return logits, DenseKVCache(data=data, lengths=lengths)


class Server:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 4096,
                 dtype=jnp.bfloat16, buckets=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.dtype = dtype
        self.bucketed = R.supports_paged(cfg)
        self.buckets = tuple(sorted(buckets)) if buckets else \
            pow2_buckets(max_len)
        self._prefill_fns = {}          # (batch, bucket) -> jit
        self._prefill = jax.jit(partial(
            R.prefill, cfg=cfg, cache_len_cap=max_len, dtype=dtype))
        self._decode = jax.jit(partial(
            R.decode_step, cfg=cfg, dtype=dtype))

    @property
    def n_prefill_executables(self) -> int:
        """Distinct prefill executables on the bucketed path — bounded
        by #batch-sizes x #buckets, not by distinct prompt lengths."""
        return len(self._prefill_fns)

    def _bucket_for(self, s: int) -> int:
        for b in self.buckets:
            if s <= b:
                return b
        raise ValueError(f"prompt length {s} exceeds largest bucket "
                         f"{self.buckets[-1]}")

    def _prefill_bucketed(self, tokens, prefix_emb):
        B, S = tokens.shape
        n_prefix = 0 if prefix_emb is None else prefix_emb.shape[1]
        bucket = self._bucket_for(S)
        if n_prefix + bucket > self.max_len:
            raise ValueError(
                f"prompt bucket {bucket} (+{n_prefix} prefix) exceeds "
                f"max_len {self.max_len}")
        padded = jnp.pad(tokens, ((0, 0), (0, bucket - S)))
        lengths = jnp.full((B,), n_prefix + S, jnp.int32)
        key = (B, bucket)
        fn = self._prefill_fns.get(key)
        if fn is None:
            fn = jax.jit(partial(_bucketed_prefill, cfg=self.cfg,
                                 cache_len_cap=self.max_len,
                                 dtype=self.dtype))
            self._prefill_fns[key] = fn
        return fn(self.params, padded, lengths, prefix_emb)

    def generate(self, tokens: np.ndarray, n_new: int, *,
                 prefix_emb=None, temperature: float = 0.0,
                 seed: int = 0) -> np.ndarray:
        """tokens: (B, S) prompt.  Returns (B, n_new) generated ids.

        .. deprecated:: blocking whole-batch generation; prefer
           ``serving.ServingEngine`` (submit/step/drain), which serves
           ragged prompts and generation budgets without padding the
           batch or blocking on its slowest member."""
        warnings.warn(
            "Server.generate blocks the whole batch on its slowest "
            "request; prefer serving.ServingEngine.submit()/drain() "
            "(Server remains the dense parity oracle and the path for "
            "families without a paged/state serving mode)",
            DeprecationWarning, stacklevel=2)
        tokens = jnp.asarray(tokens, jnp.int32)
        if self.bucketed:
            logits, cache = self._prefill_bucketed(tokens, prefix_emb)
        else:
            logits, cache = self._prefill(
                params=self.params, tokens=tokens, prefix_emb=prefix_emb)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits, temperature, key)
        out.append(tok)
        for _ in range(n_new - 1):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(
                params=self.params, cache=cache, token=tok)
            tok = self._sample(logits, temperature, sub)
            out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))

    @staticmethod
    def _sample(logits, temperature, key):
        last = logits[:, -1]
        if temperature <= 0.0:
            return jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(
            key, last / temperature)[:, None].astype(jnp.int32)
