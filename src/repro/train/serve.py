"""Batched serving runtime: prefill + greedy/temperature decode loop over
the KV-cache step functions, with a per-(batch, prompt-len) compiled
cache mirroring the trainer's per-batch-size cache."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry as R


class Server:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 4096,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.dtype = dtype
        self._prefill = jax.jit(partial(
            R.prefill, cfg=cfg, cache_len_cap=max_len, dtype=dtype),
            static_argnames=())
        self._decode = jax.jit(partial(
            R.decode_step, cfg=cfg, dtype=dtype))

    def generate(self, tokens: np.ndarray, n_new: int, *,
                 prefix_emb=None, temperature: float = 0.0,
                 seed: int = 0) -> np.ndarray:
        """tokens: (B, S) prompt.  Returns (B, n_new) generated ids."""
        tokens = jnp.asarray(tokens, jnp.int32)
        logits, cache, ln = self._prefill(
            params=self.params, tokens=tokens, prefix_emb=prefix_emb)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits, temperature, key)
        out.append(tok)
        for i in range(n_new - 1):
            key, sub = jax.random.split(key)
            logits, cache, ln = self._decode(
                params=self.params, cache=cache, cache_len=ln, token=tok)
            tok = self._sample(logits, temperature, sub)
            out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))

    @staticmethod
    def _sample(logits, temperature, key):
        last = logits[:, -1]
        if temperature <= 0.0:
            return jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(
            key, last / temperature)[:, None].astype(jnp.int32)
