from repro.train.trainer import Trainer, TrainState, make_train_step
from repro.train import checkpoint, engine
from repro.train.engine import PhaseEngine, make_grad_step

__all__ = ["Trainer", "TrainState", "make_train_step", "checkpoint",
           "engine", "PhaseEngine", "make_grad_step"]
