from repro.train.trainer import Trainer, TrainState, make_train_step
from repro.train import checkpoint

__all__ = ["Trainer", "TrainState", "make_train_step", "checkpoint"]
