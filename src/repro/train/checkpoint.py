"""Sharded, streaming checkpoints for multi-process runs.

A checkpoint is a **directory** (``path`` with any trailing ``.npz``
stripped)::

    <base>/
      manifest.json     # THE commit marker, swapped in by one
                        # os.replace: array index (key -> shape/dtype/
                        # shard files) + the save's generation + meta
      meta.json         # informational sidecar copy of the meta
      arrays/<gen>/     # one .npy per distinct global block of each
        00042.0.npy     # leaf: <leaf index in sorted key order>.<block>

Each process writes only the blocks for which it holds the
``replica_id == 0`` addressable shard, so every block is written exactly
once globally and no process ever fetches replicas it does not own.
Device->host transfers go through :func:`_to_host` in ``chunk_bytes``
slices, so saving works for params larger than host RAM (bounded
memory per transfer).  Process 0 commits the manifest after a
cross-process barrier, so a manifest on disk implies every shard file
it names is complete — and because each save streams into a fresh
``arrays/<generation>/`` and the previous generation is deleted only
after the commit, a save killed at ANY point leaves the last committed
checkpoint fully restorable.

Restore is the mirror image: every process reads only the block its
target sharding makes addressable (shard files are memory-mapped, so a
block read touches only the bytes it needs) and the global array is
reassembled with ``jax.make_array_from_process_local_data``.  Legacy
pre-PR-5 single-file ``<base>.npz`` checkpoints (see :func:`save_npz`)
restore through the same path, including float ``tokens_seen`` metadata
from before the exact-integer change.

Phase-aware save/resume: ``save_phase_checkpoint`` records the plan
position (phase index, batch size, schedule kind) next to
``tokens_seen``; ``restore_phase_checkpoint`` validates that the
restoring run's plan lands the same token count in the same phase, so
the engine resumes with the correct compiled step (batch size) and the
device-side LR curve picks up exactly where it left off.

``tokens_seen`` round-trips losslessly: the trainer passes an exact
Python int and JSON preserves arbitrary-precision integers, so a
resumed run continues from the exact token count however long the run
(pre-integer float checkpoints still restore -- the trainer rounds)."""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

FORMAT_VERSION = 1
DEFAULT_CHUNK_BYTES = 1 << 24          # 16 MiB per device->host slice

Block = Tuple[Tuple[int, int], ...]    # ((start, stop), ...) per dim


def _to_host(x) -> np.ndarray:
    """The single device->host transfer point of the save path.  Every
    call moves at most one ``chunk_bytes`` slice of one shard — tests
    monkeypatch this to prove no full replica is ever materialized."""
    return np.asarray(x)


def _barrier(name: str):
    if jax.process_count() > 1:
        from jax.experimental import multihost_utils
        multihost_utils.sync_global_devices(name)


# --------------------------------------------------------------------- #
# pytree <-> flat path-keyed dict
# --------------------------------------------------------------------- #

def _flatten(tree, prefix="") -> Dict[str, Any]:
    """Path-flatten a pytree; leaves are kept as-is (jax.Array leaves
    are NOT fetched to host — the save path streams their shards)."""
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}[{i}]/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten(template, flat: Dict[str, Any], prefix=""):
    """Rebuild the template's structure from leaf values in ``flat``
    (values are used verbatim — the assembly step already produced
    correctly-typed, correctly-sharded arrays)."""
    if isinstance(template, dict):
        return {k: _unflatten(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        vals = [_unflatten(v, flat, f"{prefix}[{i}]/")
                for i, v in enumerate(template)]
        return type(template)(vals)
    return flat[prefix.rstrip("/")]


def _base(path: str) -> str:
    return path[:-4] if path.endswith(".npz") else path


# --------------------------------------------------------------------- #
# block geometry
# --------------------------------------------------------------------- #

def _norm_index(idx, shape) -> Block:
    """A devices_indices_map slice tuple as ((start, stop), ...)."""
    return tuple((sl.start or 0, shape[d] if sl.stop is None else sl.stop)
                 for d, sl in enumerate(idx))


def _full_block(shape) -> Block:
    return tuple((0, n) for n in shape)


def _volume(block: Block) -> int:
    v = 1
    for a, b in block:
        v *= b - a
    return v


def _is_private(leaf) -> bool:
    """In a multi-process run, a fully-addressable array is a
    process-private replica (e.g. freshly-initialized state before the
    first sharded step): every process holds an identical copy, so
    process 0's is canonical and the others must not race to write."""
    return (jax.process_count() > 1
            and leaf.sharding.is_fully_addressable)


def _global_blocks(leaf):
    """(shape, dtype, ordered distinct global blocks) for a leaf —
    identical on every process (``devices_indices_map`` is global
    topology), which is what lets process 0 write a manifest naming
    files other processes produced."""
    if isinstance(leaf, jax.Array):
        shape = tuple(leaf.shape)
        if _is_private(leaf):
            return shape, np.dtype(leaf.dtype), [_full_block(shape)]
        imap = leaf.sharding.devices_indices_map(shape)
        blocks = sorted({_norm_index(i, shape) for i in imap.values()})
        return shape, np.dtype(leaf.dtype), blocks
    arr = np.asarray(leaf)
    return tuple(arr.shape), arr.dtype, [_full_block(arr.shape)]


def _writer_blocks(leaf) -> Dict[Block, Any]:
    """The blocks THIS process must write: its addressable
    ``replica_id == 0`` shards (exactly one process owns replica 0 of
    each block, so each file has a unique writer)."""
    if isinstance(leaf, jax.Array):
        shape = tuple(leaf.shape)
        if _is_private(leaf):
            return ({_full_block(shape): leaf}
                    if jax.process_index() == 0 else {})
        return {_norm_index(s.index, shape): s.data
                for s in leaf.addressable_shards if s.replica_id == 0}
    if jax.process_index() == 0:
        arr = np.asarray(leaf)
        return {_full_block(arr.shape): arr}
    return {}


def _stream_write(path: str, data, chunk_bytes: int):
    """Write one shard to a .npy file in bounded-memory slices: the
    shard is viewed flat and copied ``chunk_bytes`` at a time, so no
    single device→host transfer ever exceeds the chunk whatever the
    shard's row shape (device arrays are sliced on device)."""
    shape = tuple(data.shape)
    dtype = np.dtype(data.dtype)
    mm = np.lib.format.open_memmap(path, mode="w+", dtype=dtype,
                                   shape=shape)
    try:
        flat = mm.reshape(-1)             # writes through to the file
        src = data.reshape(-1)
        elems = max(1, int(chunk_bytes) // max(dtype.itemsize, 1))
        for i in range(0, flat.shape[0], elems):
            flat[i:i + elems] = _to_host(src[i:i + elems])
        mm.flush()
    finally:
        del mm


def _shard_file(gen: int, leaf_i: int, block_j: int) -> str:
    return os.path.join("arrays", str(gen),
                        f"{leaf_i:05d}.{block_j}.npy")


def _committed_generation(base: str) -> int:
    """Generation of the currently-committed manifest, or -1.  Every
    process reads the same committed manifest, so the next generation
    number is agreed on without communication."""
    try:
        with open(os.path.join(base, "manifest.json")) as f:
            return int(json.load(f).get("generation", 0))
    except (FileNotFoundError, json.JSONDecodeError):
        return -1


# --------------------------------------------------------------------- #
# save
# --------------------------------------------------------------------- #

def save(path: str, params, opt_state, step: int, tokens_seen: int,
         extra: Optional[Dict[str, Any]] = None, *,
         chunk_bytes: int = DEFAULT_CHUNK_BYTES):
    """Write a sharded streaming checkpoint directory at ``path`` (any
    trailing ``.npz`` is stripped — the name stays launcher-compatible).
    Safe to call from every process of a multi-process run; collective
    (all processes must call it).

    Crash-safe: shards stream into a fresh ``arrays/<generation>/``
    while the previous generation and its manifest stay untouched, and
    the new manifest lands in one ``os.replace`` — a save killed at
    any point leaves the last committed checkpoint fully restorable
    (uncommitted generations are garbage-collected by the next
    save)."""
    base = _base(path)
    parent = os.path.dirname(base)
    flat = {}
    flat.update({f"p:{k}": v for k, v in _flatten(params).items()})
    flat.update({f"o:{k}": v for k, v in _flatten(opt_state).items()})

    committed = _committed_generation(base)
    gen = committed + 1
    arrays_root = os.path.join(base, "arrays")
    gen_dir = os.path.join(arrays_root, str(gen))
    # every process must have ENTERED the save (i.e. finished whatever
    # it was still reading from this directory — e.g. a slower peer's
    # restore when resuming and re-saving to the same path) before
    # process 0 touches the directory
    _barrier("ckpt-enter")
    if jax.process_index() == 0:
        os.makedirs(parent or ".", exist_ok=True)
        if os.path.isdir(arrays_root):
            # clear leftovers of interrupted saves; the committed
            # generation stays restorable until the new one commits
            for entry in os.listdir(arrays_root):
                if entry != str(committed):
                    shutil.rmtree(os.path.join(arrays_root, entry))
        os.makedirs(gen_dir, exist_ok=True)
    _barrier("ckpt-prepare")

    meta = {"step": int(step), "tokens_seen": tokens_seen,
            **(extra or {})}
    manifest = {"format": FORMAT_VERSION, "generation": gen,
                "meta": meta, "arrays": {}}
    for li, (key, leaf) in enumerate(sorted(flat.items())):
        shape, dtype, blocks = _global_blocks(leaf)
        mine = _writer_blocks(leaf)
        shards = []
        for j, blk in enumerate(blocks):
            fname = _shard_file(gen, li, j)
            shards.append({"file": fname,
                           "start": [a for a, _ in blk],
                           "stop": [b for _, b in blk]})
            if blk in mine:
                _stream_write(os.path.join(base, fname), mine[blk],
                              chunk_bytes)
        manifest["arrays"][key] = {"shape": list(shape),
                                   "dtype": dtype.name,
                                   "shards": shards}
    _barrier("ckpt-shards")

    if jax.process_index() == 0:
        # single-rename commit point; meta rides inside the manifest
        # so array index and step/tokens can never disagree.  The
        # meta.json sidecar is informational (humans, tooling).
        tmp = os.path.join(base, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(base, "manifest.json"))
        with open(os.path.join(base, "meta.json"), "w") as f:
            json.dump(meta, f)
        # superseded state goes only AFTER the commit: the previous
        # generation — and, on the first directory save over a legacy
        # path, the old single-file .npz — must stay restorable while
        # this save can still fail
        old_gen = os.path.join(arrays_root, str(committed))
        if committed >= 0 and os.path.isdir(old_gen):
            shutil.rmtree(old_gen)
        for stale in (base + ".npz", base + ".meta.json"):
            if os.path.exists(stale):
                os.remove(stale)
    _barrier("ckpt-commit")


def save_npz(path: str, params, opt_state, step: int, tokens_seen,
             extra: Optional[Dict[str, Any]] = None):
    """The legacy pre-PR-5 writer: one monolithic ``<base>.npz`` with
    every array fetched to host, plus ``<base>.meta.json``.  Kept for
    the migration tests and for producing old-format fixtures; new code
    should use :func:`save`."""
    base = _base(path)
    os.makedirs(os.path.dirname(base) or ".", exist_ok=True)
    flat = {}
    flat.update({f"p:{k}": np.asarray(v)
                 for k, v in _flatten(params).items()})
    flat.update({f"o:{k}": np.asarray(v)
                 for k, v in _flatten(opt_state).items()})
    np.savez(base + ".npz", **flat)
    meta = {"step": step, "tokens_seen": tokens_seen, **(extra or {})}
    with open(base + ".meta.json", "w") as f:
        json.dump(meta, f)


# --------------------------------------------------------------------- #
# restore
# --------------------------------------------------------------------- #

def _local_box(sharding, gshape) -> Tuple[Block, ...]:
    """This process's contiguous block of the global array under
    ``sharding``: the bounding box of its addressable shard indices,
    verified to be exactly tiled by them (the layout
    ``make_array_from_process_local_data`` requires)."""
    imap = sharding.addressable_devices_indices_map(gshape)
    blocks = {_norm_index(i, gshape) for i in imap.values()}
    if not gshape:
        return ()
    box = tuple((min(b[d][0] for b in blocks),
                 max(b[d][1] for b in blocks))
                for d in range(len(gshape)))
    if sum(_volume(b) for b in blocks) != _volume(box):
        raise ValueError(
            f"process {jax.process_index()}'s addressable shards "
            f"{sorted(blocks)} do not tile a contiguous block of the "
            f"global array {gshape} — this sharding cannot be "
            f"reassembled with jax.make_array_from_process_local_data")
    return box


def _fill_block(out: np.ndarray, box: Block, saved_blocks):
    """Fill ``out`` (the local box) from whichever saved shard blocks
    overlap it; each ``reader()`` memory-maps one shard file, so only
    the overlapping bytes are actually read."""
    for sb, reader in saved_blocks:
        lo = tuple(max(a, c) for (a, _), (c, _) in zip(box, sb))
        hi = tuple(min(b, d) for (_, b), (_, d) in zip(box, sb))
        if any(l >= h for l, h in zip(lo, hi)):
            continue
        src_sl = tuple(slice(l - c, h - c)
                       for l, h, (c, _) in zip(lo, hi, sb))
        dst_sl = tuple(slice(l - a, h - a)
                       for l, h, (a, _) in zip(lo, hi, box))
        out[dst_sl] = reader()[src_sl]


def _entry_blocks(entry, base):
    """(saved block, lazy memmap reader) per shard file of a manifest
    entry.  0-d arrays are read eagerly (memmap of a scalar is not
    worth the bookkeeping)."""
    out = []
    for sh in entry["shards"]:
        blk = tuple(zip(sh["start"], sh["stop"]))
        fpath = os.path.join(base, sh["file"])
        if blk:
            out.append((blk, lambda p=fpath: np.load(p, mmap_mode="r")))
        else:
            out.append((blk, lambda p=fpath: np.load(p)))
    return out


def _assemble(gshape, template, sharding, saved_blocks):
    """One leaf: read this process's block and build the output array.
    Without a target sharding the full array is read onto the single
    local device (the single-process path); with one, only the
    process-local box is ever materialized on host."""
    dtype = np.dtype(template.dtype)
    if not gshape:                              # scalars: read eagerly
        _, reader = saved_blocks[0]
        val = np.asarray(reader(), dtype)
        if sharding is None:
            return jax.numpy.asarray(val, dtype=template.dtype)
        return jax.make_array_from_process_local_data(sharding, val, ())
    box = (_full_block(gshape) if sharding is None
           else _local_box(sharding, gshape))
    local = np.empty(tuple(b - a for a, b in box), dtype)
    _fill_block(local, box, saved_blocks)
    if sharding is None:
        return jax.numpy.asarray(local, dtype=template.dtype)
    return jax.make_array_from_process_local_data(sharding, local,
                                                  gshape)


def _tree_shardings(shardings, template):
    if shardings is None:
        return {k: None for k in _flatten(template)}
    return _flatten(shardings)


def _restore_manifest(base: str, params_template, opt_template,
                      shardings) -> Tuple[Any, Any, Dict[str, Any]]:
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)
    meta = manifest["meta"]       # committed atomically with the index
    psh, osh = shardings if shardings is not None else (None, None)
    out = []
    for prefix, template, sh in (("p:", params_template, psh),
                                 ("o:", opt_template, osh)):
        flat_t = _flatten(template)
        flat_s = _tree_shardings(sh, template)
        flat = {}
        for k, tmpl in flat_t.items():
            entry = manifest["arrays"][prefix + k]
            flat[k] = _assemble(tuple(entry["shape"]), tmpl,
                                flat_s[k], _entry_blocks(entry, base))
        out.append(_unflatten(template, flat))
    return out[0], out[1], meta


def _restore_legacy_npz(base: str, params_template, opt_template,
                        shardings) -> Tuple[Any, Any, Dict[str, Any]]:
    """Pre-PR-5 single-file checkpoints through the same assembly path:
    each whole array is one saved block, so a sharded restore still
    slices out only the process-local box before device placement."""
    data = np.load(base + ".npz")
    with open(base + ".meta.json") as f:
        meta = json.load(f)
    psh, osh = shardings if shardings is not None else (None, None)
    out = []
    for prefix, template, sh in (("p:", params_template, psh),
                                 ("o:", opt_template, osh)):
        flat_t = _flatten(template)
        flat_s = _tree_shardings(sh, template)
        flat = {}
        for k, tmpl in flat_t.items():
            arr = data[prefix + k]
            blocks = [(_full_block(arr.shape), lambda a=arr: a)]
            flat[k] = _assemble(tuple(arr.shape), tmpl, flat_s[k],
                                blocks)
        out.append(_unflatten(template, flat))
    return out[0], out[1], meta


def restore(path: str, params_template, opt_template, *,
            shardings: Optional[Tuple[Any, Any]] = None
            ) -> Tuple[Any, Any, Dict[str, Any]]:
    """Restore ``(params, opt_state, meta)`` from a checkpoint at
    ``path`` — a sharded directory (preferred) or a legacy single-file
    ``.npz``.  ``shardings`` is an optional ``(param_tree, opt_tree)``
    of target ``NamedSharding``s (see
    ``PhaseEngine.state_shardings``): with it, every process reads and
    device-puts only its addressable block and the global arrays are
    reassembled across processes; without it, arrays land replicated on
    the local default device (single-process behaviour)."""
    base = _base(path)
    if os.path.exists(os.path.join(base, "manifest.json")):
        return _restore_manifest(base, params_template, opt_template,
                                 shardings)
    if os.path.exists(base + ".npz"):
        return _restore_legacy_npz(base, params_template, opt_template,
                                   shardings)
    raise FileNotFoundError(
        f"no checkpoint at {path!r}: neither {base}/manifest.json "
        f"(sharded directory) nor {base}.npz (legacy single-file)")


def exact_tokens(tokens_seen) -> int:
    """A checkpoint's ``tokens_seen`` as an exact int.  Post-PR-4
    metadata is already an arbitrary-precision JSON int and must NOT
    round-trip through float64 (exact only to 2^53); legacy float
    values are rounded (their step boundaries are integral)."""
    if isinstance(tokens_seen, int):
        return tokens_seen
    return int(round(float(tokens_seen)))


# --------------------------------------------------------------------- #
# phase-aware save/resume
# --------------------------------------------------------------------- #

def _plan_phase(plan, tokens_seen: int, seq_len):
    """Phase the next step belongs to — realized (step-quantized)
    boundaries when seq_len is known, matching the loader and the
    device LR; ideal token boundaries otherwise."""
    if seq_len:
        return plan.realized_phase_at(tokens_seen, seq_len)
    return plan.phase_at_tokens(tokens_seen)


def save_phase_checkpoint(path: str, params, opt_state, step: int,
                          tokens_seen: int, *, plan,
                          seq_len: Optional[int] = None,
                          extra: Optional[Dict[str, Any]] = None,
                          chunk_bytes: int = DEFAULT_CHUNK_BYTES):
    """Like :func:`save`, plus the plan position at ``tokens_seen``:
    the phase the *next* step belongs to and its batch size.
    ``tokens_seen`` is the trainer's exact host integer."""
    ph = _plan_phase(plan, tokens_seen, seq_len)
    meta = {"phase": ph.index, "batch_size": ph.batch_size,
            "schedule_kind": plan.kind,
            "total_tokens": plan.total_tokens, **(extra or {})}
    save(path, params, opt_state, step, tokens_seen, extra=meta,
         chunk_bytes=chunk_bytes)


def restore_phase_checkpoint(path: str, params_template, opt_template,
                             *, plan, seq_len: Optional[int] = None,
                             shardings: Optional[Tuple[Any, Any]] = None
                             ) -> Tuple[Any, Any, Dict[str, Any]]:
    """Restore and verify the plan agrees with the checkpoint: the
    restored ``tokens_seen`` must land in the recorded phase with the
    recorded batch size, or the resumed run would silently train with
    the wrong compiled step / LR scale.  ``tokens_seen`` in the
    returned meta is an exact int for post-PR-4 checkpoints and a float
    for legacy ones (callers round — boundaries are integral)."""
    params, opt, meta = restore(path, params_template, opt_template,
                                shardings=shardings)
    if "phase" in meta:
        tok = exact_tokens(meta["tokens_seen"])
        ph = _plan_phase(plan, tok, seq_len)
        if (ph.index != meta["phase"]
                or ph.batch_size != meta["batch_size"]):
            raise ValueError(
                f"checkpoint was saved in phase {meta['phase']} "
                f"(batch {meta['batch_size']}) but this plan puts "
                f"tokens_seen={tok} in phase "
                f"{ph.index} (batch {ph.batch_size}) — schedule "
                f"mismatch between save and resume")
    return params, opt, meta
