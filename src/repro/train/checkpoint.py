"""Checkpointing: params/opt-state/step/tokens to a single .npz with
path-flattened keys — dependency-free, works for any pytree of arrays.
Seesaw phase boundaries are the natural checkpoint points (the batch
size of the resumed phase is recovered from the plan + tokens_seen)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}[{i}]/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _unflatten_into(template, flat: Dict[str, np.ndarray], prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        vals = [_unflatten_into(v, flat, f"{prefix}[{i}]/")
                for i, v in enumerate(template)]
        return type(template)(vals)
    arr = flat[prefix.rstrip("/")]
    return jax.numpy.asarray(arr, dtype=template.dtype)


def _base(path: str) -> str:
    return path[:-4] if path.endswith(".npz") else path


def save(path: str, params, opt_state, step: int, tokens_seen: float,
         extra: Dict[str, Any] | None = None):
    base = _base(path)
    os.makedirs(os.path.dirname(base) or ".", exist_ok=True)
    flat = {}
    flat.update({f"p:{k}": v for k, v in _flatten(params).items()})
    flat.update({f"o:{k}": v for k, v in _flatten(opt_state).items()})
    np.savez(base + ".npz", **flat)
    meta = {"step": step, "tokens_seen": tokens_seen, **(extra or {})}
    with open(base + ".meta.json", "w") as f:
        json.dump(meta, f)


def restore(path: str, params_template, opt_template
            ) -> Tuple[Any, Any, Dict[str, Any]]:
    base = _base(path)
    data = np.load(base + ".npz")
    flat_p = {k[2:]: data[k] for k in data.files if k.startswith("p:")}
    flat_o = {k[2:]: data[k] for k in data.files if k.startswith("o:")}
    params = _unflatten_into(params_template, flat_p)
    opt = _unflatten_into(opt_template, flat_o)
    with open(base + ".meta.json") as f:
        meta = json.load(f)
    return params, opt, meta
