"""Sharded, streaming, async-capable checkpoints for multi-process runs.

A checkpoint is a **directory** (``path`` with any trailing ``.npz``
stripped)::

    <base>/
      manifest.json     # THE commit marker, swapped in by one
                        # os.replace: array index (key -> shape/dtype/
                        # shard files) + the save's generation + meta
      meta.json         # informational sidecar copy of the meta
      arrays/<gen>/     # one .npy per distinct global block of each
        00042.0.npy     # leaf: <leaf index in sorted key order>.<block>
      .save-<gen>.<p>.json   # transient per-process completion marker
                             # (block checksums), removed at commit

Each global block of every leaf is written by exactly one process —
assigned **round-robin across every process that holds an addressable
copy of the block** (any replica, replicas are bitwise-identical), so
replicated and model-parallel-sharded state spreads its write bandwidth
over all hosts instead of bottlenecking the data-row-0 process.  The
assignment is derived from the global ``devices_indices_map`` on every
process identically, recorded in the manifest (``"writer"``), and needs
no communication.  Device->host transfers go through :func:`_to_host`
in ``chunk_bytes`` slices, so saving works for params larger than host
RAM; each block's crc32 is accumulated during the stream and lands in
the manifest for ``restore(..., verify=True)``.

Commit protocol: every process streams its blocks into a fresh
``arrays/<generation>/`` and then drops an atomic marker file carrying
its checksums; process 0 merges the markers, commits the manifest in a
single ``os.replace``, and only then garbage-collects the previous
generation.  A save killed at ANY point — including one process dying
mid-save — leaves the last committed checkpoint fully restorable, and
the survivors surface a :class:`CheckpointTimeoutError` instead of
hanging.  The synchronous :func:`save` wraps the same steps in
cross-process barriers; :class:`CheckpointManager` runs the streaming
and commit from a background thread (no jax collectives off the main
thread) so the step loop is blocked only for the on-device snapshot.

Restore is the mirror image: every process reads only the block its
target sharding makes addressable (shard files are memory-mapped, so a
block read touches only the bytes it needs) and the global array is
reassembled with ``jax.make_array_from_process_local_data``.  The
on-disk format is **topology-independent**: a checkpoint saved on N
processes restores onto M processes or a different mesh shape (elastic
resume) — only the *feeding* side needs re-validation, see
``launch.steps.validate_feeding(start_tokens=...)``.  Legacy pre-PR-5
single-file ``<base>.npz`` checkpoints (see :func:`save_npz`) restore
through the same path, including float ``tokens_seen`` metadata from
before the exact-integer change (non-integral values now warn instead
of silently rounding).

Phase-aware save/resume: ``save_phase_checkpoint`` records the plan
position (phase index, batch size, schedule kind) next to
``tokens_seen``; ``restore_phase_checkpoint`` validates that the
restoring run's plan lands the same token count in the same phase, so
the engine resumes with the correct compiled step (batch size) and the
device-side LR curve picks up exactly where it left off."""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import warnings
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

FORMAT_VERSION = 2                     # v2 adds writer + crc32 per block
DEFAULT_CHUNK_BYTES = 1 << 24          # 16 MiB per device->host slice
DEFAULT_COMMIT_TIMEOUT = 600.0         # s to wait on peers before failing

Block = Tuple[Tuple[int, int], ...]    # ((start, stop), ...) per dim


class CheckpointError(RuntimeError):
    """Base class for checkpoint failures."""


class CheckpointCorruptionError(CheckpointError):
    """A shard file's content does not match its manifest checksum."""


class CheckpointTimeoutError(CheckpointError):
    """A peer process never finished its part of a save — it likely
    died mid-save.  The previously committed generation is intact."""


def _to_host(x) -> np.ndarray:
    """The single device->host transfer point of the save path.  Every
    call moves at most one ``chunk_bytes`` slice of one shard — tests
    monkeypatch this to prove no full replica is ever materialized."""
    return np.asarray(x)


def _barrier(name: str):
    if jax.process_count() > 1:
        from jax.experimental import multihost_utils
        multihost_utils.sync_global_devices(name)


# --------------------------------------------------------------------- #
# pytree <-> flat path-keyed dict
# --------------------------------------------------------------------- #

def _flatten(tree, prefix="") -> Dict[str, Any]:
    """Path-flatten a pytree; leaves are kept as-is (jax.Array leaves
    are NOT fetched to host — the save path streams their shards)."""
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}[{i}]/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten(template, flat: Dict[str, Any], prefix=""):
    """Rebuild the template's structure from leaf values in ``flat``
    (values are used verbatim — the assembly step already produced
    correctly-typed, correctly-sharded arrays)."""
    if isinstance(template, dict):
        return {k: _unflatten(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        vals = [_unflatten(v, flat, f"{prefix}[{i}]/")
                for i, v in enumerate(template)]
        return type(template)(vals)
    return flat[prefix.rstrip("/")]


def _flat_state(params, opt_state) -> Dict[str, Any]:
    flat: Dict[str, Any] = {}
    flat.update({f"p:{k}": v for k, v in _flatten(params).items()})
    flat.update({f"o:{k}": v for k, v in _flatten(opt_state).items()})
    return flat


def _base(path: str) -> str:
    return path[:-4] if path.endswith(".npz") else path


# --------------------------------------------------------------------- #
# block geometry + writer assignment
# --------------------------------------------------------------------- #

def _norm_index(idx, shape) -> Block:
    """A devices_indices_map slice tuple as ((start, stop), ...)."""
    return tuple((sl.start or 0, shape[d] if sl.stop is None else sl.stop)
                 for d, sl in enumerate(idx))


def _full_block(shape) -> Block:
    return tuple((0, n) for n in shape)


def _volume(block: Block) -> int:
    v = 1
    for a, b in block:
        v *= b - a
    return v


def _is_private(leaf) -> bool:
    """In a multi-process run, a fully-addressable array is a
    process-private replica (e.g. freshly-initialized state before the
    first sharded step): every process holds an identical copy, so any
    one of them can serve as the writer."""
    return (jax.process_count() > 1
            and leaf.sharding.is_fully_addressable)


def _block_table(leaf):
    """(shape, dtype, ordered distinct global blocks, {block: sorted
    process indices holding an addressable copy of it}) for a leaf —
    identical on every process (``devices_indices_map`` is global
    topology), which is what lets the round-robin writer assignment be
    agreed without communication and lets process 0 write a manifest
    naming files other processes produced."""
    all_procs = list(range(jax.process_count()))
    if isinstance(leaf, jax.Array):
        shape = tuple(leaf.shape)
        if _is_private(leaf):
            blk = _full_block(shape)
            return shape, np.dtype(leaf.dtype), [blk], {blk: all_procs}
        imap = leaf.sharding.devices_indices_map(shape)
        holders: Dict[Block, set] = {}
        for dev, idx in imap.items():
            holders.setdefault(_norm_index(idx, shape),
                               set()).add(dev.process_index)
        blocks = sorted(holders)
        return (shape, np.dtype(leaf.dtype), blocks,
                {b: sorted(holders[b]) for b in blocks})
    arr = np.asarray(leaf)
    blk = _full_block(arr.shape)
    return tuple(arr.shape), arr.dtype, [blk], {blk: all_procs}


def _local_blocks(leaf) -> Dict[Block, Any]:
    """The shard data this process can serve, per block.  Any replica
    works — replicas are bitwise-identical — so a process assigned a
    block it holds only as replica k just streams that copy."""
    if isinstance(leaf, jax.Array):
        shape = tuple(leaf.shape)
        if _is_private(leaf):
            return {_full_block(shape): leaf}
        out: Dict[Block, Any] = {}
        for s in leaf.addressable_shards:
            out.setdefault(_norm_index(s.index, shape), s.data)
        return out
    arr = np.asarray(leaf)
    return {_full_block(arr.shape): arr}


def _plan_writes(flat: Dict[str, Any], gen: int):
    """(manifest ``arrays`` dict, [(shard entry, device data), ...] of
    the blocks THIS process writes).  The writer of each block rotates
    round-robin across the processes holding an addressable copy, over
    all blocks in save order — so replicated state (every process a
    candidate) and model-parallel-heavy meshes spread their write
    bandwidth across all hosts instead of funnelling through the
    data-row-0 process.  The assignment lands in the manifest."""
    arrays: Dict[str, Any] = {}
    mine: List[Tuple[Dict, Any]] = []
    rr = 0
    me = jax.process_index()
    for li, (key, leaf) in enumerate(sorted(flat.items())):
        shape, dtype, blocks, holders = _block_table(leaf)
        local = _local_blocks(leaf)
        shards = []
        for j, blk in enumerate(blocks):
            cands = holders[blk]
            writer = cands[rr % len(cands)]
            rr += 1
            ent = {"file": _shard_file(gen, li, j),
                   "start": [a for a, _ in blk],
                   "stop": [b for _, b in blk],
                   "writer": writer}
            shards.append(ent)
            if writer == me:
                mine.append((ent, local[blk]))
        arrays[key] = {"shape": list(shape), "dtype": dtype.name,
                       "shards": shards}
    return arrays, mine


def _writer_blocks(leaf) -> Dict[Block, Any]:
    """Blocks THIS process would write for a single leaf (rotation
    starting at 0) — kept for tests and introspection; the save path
    plans the rotation across all leaves via :func:`_plan_writes`."""
    shape, _, blocks, holders = _block_table(leaf)
    local = _local_blocks(leaf)
    me = jax.process_index()
    return {blk: local[blk] for j, blk in enumerate(blocks)
            if holders[blk][j % len(holders[blk])] == me}


def _stream_write(path: str, data, chunk_bytes: int) -> int:
    """Write one shard to a .npy file in bounded-memory slices: the
    shard is viewed flat and copied ``chunk_bytes`` at a time, so no
    single device→host transfer ever exceeds the chunk whatever the
    shard's row shape (device arrays are sliced on device).  Returns
    the crc32 of the streamed bytes for the manifest."""
    shape = tuple(data.shape)
    dtype = np.dtype(data.dtype)
    mm = np.lib.format.open_memmap(path, mode="w+", dtype=dtype,
                                   shape=shape)
    crc = 0
    try:
        flat = mm.reshape(-1)             # writes through to the file
        src = data.reshape(-1)
        elems = max(1, int(chunk_bytes) // max(dtype.itemsize, 1))
        for i in range(0, flat.shape[0], elems):
            h = _to_host(src[i:i + elems])
            flat[i:i + elems] = h
            crc = zlib.crc32(np.ascontiguousarray(h).tobytes(), crc)
        mm.flush()
    finally:
        del mm
    return crc


def _crc_of_file(path: str,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES) -> int:
    """crc32 of a shard file's array content, read in bounded slices
    (memory-mapped — verification never loads a whole block)."""
    arr = np.load(path, mmap_mode="r")
    flat = np.asarray(arr).reshape(-1) if arr.ndim == 0 \
        else arr.reshape(-1)
    crc = 0
    elems = max(1, int(chunk_bytes) // max(flat.dtype.itemsize, 1))
    for i in range(0, flat.shape[0], elems):
        crc = zlib.crc32(
            np.ascontiguousarray(flat[i:i + elems]).tobytes(), crc)
    return crc


def _shard_file(gen: int, leaf_i: int, block_j: int) -> str:
    return os.path.join("arrays", str(gen),
                        f"{leaf_i:05d}.{block_j}.npy")


def _committed_generation(base: str) -> int:
    """Generation of the currently-committed manifest, or -1.  Every
    process reads the same committed manifest, so the next generation
    number is agreed on without communication."""
    try:
        with open(os.path.join(base, "manifest.json")) as f:
            return int(json.load(f).get("generation", 0))
    except (FileNotFoundError, json.JSONDecodeError):
        return -1


# --------------------------------------------------------------------- #
# commit coordination (marker files)
# --------------------------------------------------------------------- #

def _marker_path(base: str, gen: int, pid: int) -> str:
    return os.path.join(base, f".save-{gen}.{pid}.json")


def _write_marker(base: str, gen: int, pid: int,
                  crcs: Dict[str, int]):
    """Atomically drop this process's completion marker: its shards
    are fully on disk, with these checksums."""
    path = _marker_path(base, gen, pid)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"process": pid, "crc32": crcs}, f)
    os.replace(tmp, path)


def _clear_markers(base: str):
    try:
        entries = os.listdir(base)
    except FileNotFoundError:
        return
    for name in entries:
        if name.startswith(".save-"):
            try:
                os.remove(os.path.join(base, name))
            except OSError:
                pass


def _apply_crcs(manifest: Dict, crcs: Dict[str, int]):
    for entry in manifest["arrays"].values():
        for sh in entry["shards"]:
            if sh["file"] in crcs:
                sh["crc32"] = crcs[sh["file"]]


def _merge_markers(base: str, gen: int, nproc: int, manifest: Dict, *,
                   timeout: float, poll: float = 0.05):
    """Process 0: wait until every process's completion marker exists,
    merge their checksums into the manifest.  A marker that never
    appears means a peer died mid-save — fail with a clear error; the
    previous committed generation is untouched."""
    deadline = time.monotonic() + timeout
    seen: set = set()
    while True:
        for pid in range(nproc):
            if pid in seen:
                continue
            p = _marker_path(base, gen, pid)
            if os.path.exists(p):
                try:
                    with open(p) as f:
                        m = json.load(f)
                except (json.JSONDecodeError, OSError):
                    continue               # racing replace; retry
                _apply_crcs(manifest, m.get("crc32", {}))
                seen.add(pid)
        if len(seen) >= nproc:
            return
        if time.monotonic() > deadline:
            raise CheckpointTimeoutError(
                f"timed out after {timeout:.0f}s waiting for save "
                f"markers from processes "
                f"{sorted(set(range(nproc)) - seen)} of generation "
                f"{gen} — a peer likely died mid-save; the previous "
                f"committed checkpoint is still restorable")
        time.sleep(poll)


def _await_commit(base: str, gen: int, timeout: float,
                  poll: float = 0.05):
    """Non-zero processes of an async save: wait for process 0's
    manifest commit so the next save's generation arithmetic agrees
    across processes."""
    deadline = time.monotonic() + timeout
    while _committed_generation(base) < gen:
        if time.monotonic() > deadline:
            raise CheckpointTimeoutError(
                f"timed out after {timeout:.0f}s waiting for process 0 "
                f"to commit generation {gen} — it likely died "
                f"mid-save; the previous committed checkpoint is "
                f"still restorable")
        time.sleep(poll)


def _prepare(base: str, *, collective: bool = True) -> Tuple[int, int]:
    """Agree on the new generation and (process 0) clear leftovers of
    interrupted saves + create the generation directory.  Collective
    when ``collective`` (the multi-process path: barriers ensure no
    peer is still reading the directory and that the directory exists
    before anyone streams into it)."""
    committed = _committed_generation(base)
    gen = committed + 1
    arrays_root = os.path.join(base, "arrays")
    gen_dir = os.path.join(arrays_root, str(gen))
    # every process must have ENTERED the save (i.e. finished whatever
    # it was still reading from this directory — e.g. a slower peer's
    # restore when resuming and re-saving to the same path) before
    # process 0 touches the directory
    if collective:
        _barrier("ckpt-enter")
    if jax.process_index() == 0:
        os.makedirs(os.path.dirname(base) or ".", exist_ok=True)
        if os.path.isdir(arrays_root):
            # clear leftovers of interrupted saves; the committed
            # generation stays restorable until the new one commits
            for entry in os.listdir(arrays_root):
                if entry != str(committed):
                    shutil.rmtree(os.path.join(arrays_root, entry))
        _clear_markers(base)
        os.makedirs(gen_dir, exist_ok=True)
    if collective:
        _barrier("ckpt-prepare")
    return committed, gen


def _write_shards(base: str, mine, chunk_bytes: int) -> Dict[str, int]:
    crcs: Dict[str, int] = {}
    for ent, data in mine:
        crcs[ent["file"]] = _stream_write(os.path.join(base, ent["file"]),
                                          data, chunk_bytes)
    return crcs


def _commit(base: str, manifest: Dict, committed: int):
    """Single-rename commit point; meta rides inside the manifest so
    array index and step/tokens can never disagree.  The meta.json
    sidecar is informational (humans, tooling).  Superseded state goes
    only AFTER the commit: the previous generation — and, on the first
    directory save over a legacy path, the old single-file .npz — must
    stay restorable while this save can still fail."""
    tmp = os.path.join(base, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(base, "manifest.json"))
    with open(os.path.join(base, "meta.json"), "w") as f:
        json.dump(manifest["meta"], f)
    old_gen = os.path.join(base, "arrays", str(committed))
    if committed >= 0 and os.path.isdir(old_gen):
        shutil.rmtree(old_gen)
    for stale in (base + ".npz", base + ".meta.json"):
        if os.path.exists(stale):
            os.remove(stale)
    _clear_markers(base)


# --------------------------------------------------------------------- #
# save (synchronous, barrier-coordinated)
# --------------------------------------------------------------------- #

def save(path: str, params, opt_state, step: int, tokens_seen: int,
         extra: Optional[Dict[str, Any]] = None, *,
         chunk_bytes: int = DEFAULT_CHUNK_BYTES,
         commit_timeout: float = DEFAULT_COMMIT_TIMEOUT):
    """Write a sharded streaming checkpoint directory at ``path`` (any
    trailing ``.npz`` is stripped — the name stays launcher-compatible).
    Safe to call from every process of a multi-process run; collective
    (all processes must call it); blocks until committed.

    Crash-safe: shards stream into a fresh ``arrays/<generation>/``
    while the previous generation and its manifest stay untouched, and
    the new manifest lands in one ``os.replace`` — a save killed at
    any point leaves the last committed checkpoint fully restorable
    (uncommitted generations are garbage-collected by the next
    save).  For saves that overlap training, use
    :class:`CheckpointManager`."""
    base = _base(path)
    flat = _flat_state(params, opt_state)
    committed, gen = _prepare(base)
    meta = {"step": int(step), "tokens_seen": tokens_seen,
            **(extra or {})}
    _run_save(base, flat, meta, committed, gen,
              chunk_bytes=chunk_bytes, commit_timeout=commit_timeout,
              barriers=True)


def _run_save(base: str, flat: Dict[str, Any], meta: Dict,
              committed: int, gen: int, *, chunk_bytes: int,
              commit_timeout: float, barriers: bool):
    """Stream this process's blocks and run the commit protocol.
    ``barriers=True`` is the synchronous path (cross-process barriers
    around the commit); ``barriers=False`` is the async writer-thread
    path, which must not issue jax collectives and coordinates through
    the marker files alone."""
    meta.setdefault("save_process_count", jax.process_count())
    arrays, mine = _plan_writes(flat, gen)
    manifest = {"format": FORMAT_VERSION, "generation": gen,
                "meta": meta, "arrays": arrays}
    crcs = _write_shards(base, mine, chunk_bytes)
    nproc = jax.process_count()
    me = jax.process_index()
    if nproc > 1:
        _write_marker(base, gen, me, crcs)
        if barriers:
            _barrier("ckpt-shards")
        if me == 0:
            _merge_markers(base, gen, nproc, manifest,
                           timeout=commit_timeout)
            _commit(base, manifest, committed)
        elif not barriers:
            _await_commit(base, gen, commit_timeout)
        if barriers:
            _barrier("ckpt-commit")
    else:
        _apply_crcs(manifest, crcs)
        _commit(base, manifest, committed)


def save_npz(path: str, params, opt_state, step: int, tokens_seen,
             extra: Optional[Dict[str, Any]] = None):
    """The legacy pre-PR-5 writer: one monolithic ``<base>.npz`` with
    every array fetched to host, plus ``<base>.meta.json``.  Kept for
    the migration tests and for producing old-format fixtures; new code
    should use :func:`save`."""
    base = _base(path)
    os.makedirs(os.path.dirname(base) or ".", exist_ok=True)
    flat = {}
    flat.update({f"p:{k}": np.asarray(v)
                 for k, v in _flatten(params).items()})
    flat.update({f"o:{k}": np.asarray(v)
                 for k, v in _flatten(opt_state).items()})
    np.savez(base + ".npz", **flat)
    meta = {"step": step, "tokens_seen": tokens_seen, **(extra or {})}
    with open(base + ".meta.json", "w") as f:
        json.dump(meta, f)


# --------------------------------------------------------------------- #
# async manager
# --------------------------------------------------------------------- #

# Jitted so the copy cannot be elided: a bare identity hits jit's
# passthrough-output fast path (the input array is forwarded, no new
# buffers), while a traced jnp.copy compiles to a real copy whose
# outputs are fresh XLA buffers with the inputs' shardings.
_snapshot_jit = jax.jit(lambda t: jax.tree.map(jnp.copy, t))


def snapshot_tree(tree):
    """Donation-safe on-device copy of a state tree: fresh buffers
    (same shardings) that the engine's donated next step cannot alias,
    safe to stream from a background thread while training reuses the
    originals."""
    return _snapshot_jit(tree)


@dataclass
class _SaveJob:
    base: str
    params: Any
    opt_state: Any
    meta: Dict[str, Any]
    chunk_bytes: int
    # generation agreed collectively at request time (multi-process);
    # None = derive at execution time (single-process worker)
    committed: Optional[int] = None
    gen: Optional[int] = None
    requested_at: float = field(default_factory=time.monotonic)


class CheckpointManager:
    """Async, at-most-one-in-flight checkpoint writer.

    ``request_save`` snapshots the state on device (a donation-safe
    copy — the engine's next fused chunk donates the live buffers, the
    copies are fresh) and returns; a background thread streams
    device→host→disk and commits.  The step loop is blocked only for
    the snapshot dispatch, not the write.

    Multi-process coordination has two regimes:

    - the *collective* part (entry barrier, generation agreement,
      directory prep) runs on the CALLING thread — ``request_save``
      must be invoked by every process at the same chunk boundary,
      exactly like the sync :func:`save` — so the background threads
      never issue jax collectives (a writer-thread collective could
      interleave with training collectives and deadlock the mesh);
    - the *commit* is coordinated through marker files alone: process 0
      commits once every peer's marker is on disk, peers wait for the
      committed generation to advance.  A dead peer surfaces as a
      :class:`CheckpointTimeoutError` on the next ``check()`` /
      ``request_save`` / ``finalize`` instead of hanging forever, and
      the previous generation stays restorable.

    In multi-process runs every request is honored in order (a new
    request first joins the in-flight save, keeping all processes'
    save sequences in lockstep); single-process requests **coalesce**:
    while one save streams, only the newest pending request survives —
    rapid-fire requests collapse to first + latest.

    Writer-thread exceptions are captured and re-raised on the next
    ``check()``/``request_save``/``finalize`` call, never silently
    dropped; ``finalize`` joins cleanly at exit."""

    def __init__(self, *, plan=None, seq_len: Optional[int] = None,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 commit_timeout: float = DEFAULT_COMMIT_TIMEOUT):
        self.plan = plan
        self.seq_len = seq_len
        self.chunk_bytes = chunk_bytes
        self.commit_timeout = commit_timeout
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._pending: Optional[_SaveJob] = None
        self._error: Optional[BaseException] = None
        self.saves_started = 0           # introspection (tests, bench)
        self.saves_committed = 0
        self.last_stall_s = 0.0          # time the caller was blocked

    # -- error surfacing ------------------------------------------------ #
    def check(self):
        """Re-raise a background writer failure (once), e.g. at each
        chunk boundary of the step loop."""
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    @property
    def in_flight(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    # -- requests -------------------------------------------------------- #
    def request_save(self, path: str, params, opt_state, step: int,
                     tokens_seen: int,
                     extra: Optional[Dict[str, Any]] = None, *,
                     block: bool = False):
        """Snapshot the state and schedule its save.  Collective in
        multi-process runs (call at a chunk boundary on every
        process).  ``block=True`` waits for the commit (sync
        semantics through the async machinery)."""
        t0 = time.monotonic()
        self.check()
        meta: Dict[str, Any] = {"step": int(step),
                                "tokens_seen": tokens_seen}
        if self.plan is not None:
            ph = _plan_phase(self.plan, exact_tokens(tokens_seen),
                             self.seq_len)
            meta.update({"phase": ph.index,
                         "batch_size": ph.batch_size,
                         "schedule_kind": self.plan.kind,
                         "total_tokens": self.plan.total_tokens})
        meta.update(extra or {})
        multiproc = jax.process_count() > 1
        if multiproc:
            # keep every process's save sequence identical regardless
            # of relative writer speed: serialize requests
            self.wait()
            self.check()
        job = _SaveJob(base=_base(path),
                       params=snapshot_tree(params),
                       opt_state=snapshot_tree(opt_state),
                       meta=meta, chunk_bytes=self.chunk_bytes)
        if multiproc:
            job.committed, job.gen = _prepare(job.base)
            with self._lock:
                self._start_locked(job)
        else:
            with self._lock:
                if self._thread is not None and self._thread.is_alive():
                    self._pending = job      # coalesce: newest wins
                else:
                    self._start_locked(job)
        self.last_stall_s = time.monotonic() - t0
        if block:
            self.wait()
            self.check()

    def _start_locked(self, job: _SaveJob):
        self.saves_started += 1
        self._thread = threading.Thread(
            target=self._worker, args=(job,), daemon=True,
            name="ckpt-writer")
        self._thread.start()

    # -- writer thread --------------------------------------------------- #
    def _worker(self, job: _SaveJob):
        while True:
            try:
                self._execute(job)
                with self._lock:
                    self.saves_committed += 1
            except BaseException as e:       # surfaced via check()
                with self._lock:
                    self._error = e
                    self._pending = None
                    self._thread = None
                return
            with self._lock:
                job, self._pending = self._pending, None
                if job is None:
                    self._thread = None
                    return
                self.saves_started += 1

    def _execute(self, job: _SaveJob):
        if job.gen is None:                  # single-process worker
            committed, gen = _prepare(job.base, collective=False)
        else:
            committed, gen = job.committed, job.gen
        flat = _flat_state(job.params, job.opt_state)
        _run_save(job.base, flat, dict(job.meta), committed, gen,
                  chunk_bytes=job.chunk_bytes,
                  commit_timeout=self.commit_timeout, barriers=False)

    # -- joining --------------------------------------------------------- #
    def wait(self, timeout: Optional[float] = None):
        """Join the in-flight save and any pending coalesced request
        (the worker drains the pending slot before exiting)."""
        deadline = None if timeout is None \
            else time.monotonic() + timeout
        while True:
            with self._lock:
                t = self._thread
            if t is None or not t.is_alive():
                return
            t.join(0.05 if deadline is None
                   else max(min(deadline - time.monotonic(), 0.05), 0))
            if deadline is not None and time.monotonic() > deadline:
                raise CheckpointTimeoutError(
                    f"async checkpoint writer did not finish within "
                    f"{timeout:.0f}s")

    def finalize(self):
        """Join cleanly at exit and surface any writer error."""
        self.wait()
        self.check()


# --------------------------------------------------------------------- #
# restore
# --------------------------------------------------------------------- #

def _local_box(sharding, gshape) -> Tuple[Block, ...]:
    """This process's contiguous block of the global array under
    ``sharding``: the bounding box of its addressable shard indices,
    verified to be exactly tiled by them (the layout
    ``make_array_from_process_local_data`` requires)."""
    imap = sharding.addressable_devices_indices_map(gshape)
    blocks = {_norm_index(i, gshape) for i in imap.values()}
    if not gshape:
        return ()
    box = tuple((min(b[d][0] for b in blocks),
                 max(b[d][1] for b in blocks))
                for d in range(len(gshape)))
    if sum(_volume(b) for b in blocks) != _volume(box):
        raise ValueError(
            f"process {jax.process_index()}'s addressable shards "
            f"{sorted(blocks)} do not tile a contiguous block of the "
            f"global array {gshape} — this sharding cannot be "
            f"reassembled with jax.make_array_from_process_local_data")
    return box


def _fill_block(out: np.ndarray, box: Block, saved_blocks):
    """Fill ``out`` (the local box) from whichever saved shard blocks
    overlap it; each ``reader()`` memory-maps one shard file, so only
    the overlapping bytes are actually read."""
    for sb, reader in saved_blocks:
        lo = tuple(max(a, c) for (a, _), (c, _) in zip(box, sb))
        hi = tuple(min(b, d) for (_, b), (_, d) in zip(box, sb))
        if any(l >= h for l, h in zip(lo, hi)):
            continue
        src_sl = tuple(slice(l - c, h - c)
                       for l, h, (c, _) in zip(lo, hi, sb))
        dst_sl = tuple(slice(l - a, h - a)
                       for l, h, (a, _) in zip(lo, hi, box))
        out[dst_sl] = reader()[src_sl]


def _entry_blocks(entry, base):
    """(saved block, lazy memmap reader) per shard file of a manifest
    entry.  0-d arrays are read eagerly (memmap of a scalar is not
    worth the bookkeeping)."""
    out = []
    for sh in entry["shards"]:
        blk = tuple(zip(sh["start"], sh["stop"]))
        fpath = os.path.join(base, sh["file"])
        if blk:
            out.append((blk, lambda p=fpath: np.load(p, mmap_mode="r")))
        else:
            out.append((blk, lambda p=fpath: np.load(p)))
    return out


def _verify_manifest(base: str, manifest: Dict):
    """Check every block file against its manifest crc32.  Opt-in
    (``restore(..., verify=True)``): it reads every byte of the
    checkpoint, which the normal local-box restore avoids."""
    unchecked = []
    for key, entry in manifest["arrays"].items():
        for sh in entry["shards"]:
            fpath = os.path.join(base, sh["file"])
            if "crc32" not in sh:
                unchecked.append(sh["file"])
                continue
            try:
                got = _crc_of_file(fpath)
            except FileNotFoundError:
                raise CheckpointCorruptionError(
                    f"block {sh['file']} of {key!r} is named by the "
                    f"manifest but missing on disk") from None
            if got != sh["crc32"]:
                raise CheckpointCorruptionError(
                    f"checksum mismatch in block {sh['file']} of "
                    f"{key!r}: manifest crc32={sh['crc32']}, file "
                    f"crc32={got} — the checkpoint is corrupt; "
                    f"restore an older copy or retrain from the "
                    f"previous checkpoint")
    if unchecked:
        warnings.warn(
            f"{len(unchecked)} block(s) carry no checksum "
            f"(pre-checksum manifest); skipped verification for them",
            stacklevel=3)


def _assemble(gshape, template, sharding, saved_blocks):
    """One leaf: read this process's block and build the output array.
    Without a target sharding the full array is read onto the single
    local device (the single-process path); with one, only the
    process-local box is ever materialized on host."""
    dtype = np.dtype(template.dtype)
    if not gshape:                              # scalars: read eagerly
        _, reader = saved_blocks[0]
        val = np.asarray(reader(), dtype)
        if sharding is None:
            return jax.numpy.asarray(val, dtype=template.dtype)
        return jax.make_array_from_process_local_data(sharding, val, ())
    box = (_full_block(gshape) if sharding is None
           else _local_box(sharding, gshape))
    local = np.empty(tuple(b - a for a, b in box), dtype)
    _fill_block(local, box, saved_blocks)
    if sharding is None:
        return jax.numpy.asarray(local, dtype=template.dtype)
    return jax.make_array_from_process_local_data(sharding, local,
                                                  gshape)


def _tree_shardings(shardings, template):
    if shardings is None:
        return {k: None for k in _flatten(template)}
    return _flatten(shardings)


def _restore_manifest(base: str, params_template, opt_template,
                      shardings, verify: bool
                      ) -> Tuple[Any, Any, Dict[str, Any]]:
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)
    meta = manifest["meta"]       # committed atomically with the index
    if verify:
        _verify_manifest(base, manifest)
    psh, osh = shardings if shardings is not None else (None, None)
    out = []
    for prefix, template, sh in (("p:", params_template, psh),
                                 ("o:", opt_template, osh)):
        flat_t = _flatten(template)
        flat_s = _tree_shardings(sh, template)
        flat = {}
        for k, tmpl in flat_t.items():
            entry = manifest["arrays"][prefix + k]
            flat[k] = _assemble(tuple(entry["shape"]), tmpl,
                                flat_s[k], _entry_blocks(entry, base))
        out.append(_unflatten(template, flat))
    return out[0], out[1], meta


def _restore_legacy_npz(base: str, params_template, opt_template,
                        shardings) -> Tuple[Any, Any, Dict[str, Any]]:
    """Pre-PR-5 single-file checkpoints through the same assembly path:
    each whole array is one saved block, so a sharded restore still
    slices out only the process-local box before device placement."""
    data = np.load(base + ".npz")
    with open(base + ".meta.json") as f:
        meta = json.load(f)
    psh, osh = shardings if shardings is not None else (None, None)
    out = []
    for prefix, template, sh in (("p:", params_template, psh),
                                 ("o:", opt_template, osh)):
        flat_t = _flatten(template)
        flat_s = _tree_shardings(sh, template)
        flat = {}
        for k, tmpl in flat_t.items():
            arr = data[prefix + k]
            blocks = [(_full_block(arr.shape), lambda a=arr: a)]
            flat[k] = _assemble(tuple(arr.shape), tmpl, flat_s[k],
                                blocks)
        out.append(_unflatten(template, flat))
    return out[0], out[1], meta


def restore(path: str, params_template, opt_template, *,
            shardings: Optional[Tuple[Any, Any]] = None,
            verify: bool = False
            ) -> Tuple[Any, Any, Dict[str, Any]]:
    """Restore ``(params, opt_state, meta)`` from a checkpoint at
    ``path`` — a sharded directory (preferred) or a legacy single-file
    ``.npz``.  ``shardings`` is an optional ``(param_tree, opt_tree)``
    of target ``NamedSharding``s (see
    ``PhaseEngine.state_shardings``): with it, every process reads and
    device-puts only its addressable block and the global arrays are
    reassembled across processes; without it, arrays land replicated on
    the local default device (single-process behaviour).  The target
    topology need not match the saving one — the format is elastic.
    ``verify=True`` checks every block against its manifest crc32
    first and raises :class:`CheckpointCorruptionError` naming the bad
    block."""
    base = _base(path)
    if os.path.exists(os.path.join(base, "manifest.json")):
        return _restore_manifest(base, params_template, opt_template,
                                 shardings, verify)
    if os.path.exists(base + ".npz"):
        if verify:
            warnings.warn("legacy .npz checkpoints carry no "
                          "checksums; --verify-restore skipped",
                          stacklevel=2)
        return _restore_legacy_npz(base, params_template, opt_template,
                                   shardings)
    raise FileNotFoundError(
        f"no checkpoint at {path!r}: neither {base}/manifest.json "
        f"(sharded directory) nor {base}.npz (legacy single-file)")


def read_meta(path: str) -> Dict[str, Any]:
    """Read ONLY a checkpoint's metadata — no array IO.  The adaptive
    trainer needs this *before* :func:`restore_phase_checkpoint`: the
    saved controller state (``meta["adaptive"]``) determines the
    extended plan the phase/batch validation must run against."""
    base = _base(path)
    manifest = os.path.join(base, "manifest.json")
    if os.path.exists(manifest):
        with open(manifest) as f:
            return json.load(f)["meta"]
    legacy = base + ".meta.json"
    if os.path.exists(legacy):
        with open(legacy) as f:
            return json.load(f)
    raise FileNotFoundError(
        f"no checkpoint at {path!r}: neither {base}/manifest.json "
        f"(sharded directory) nor {base}.meta.json (legacy)")


def exact_tokens(tokens_seen) -> int:
    """A checkpoint's ``tokens_seen`` as an exact int.  Post-PR-4
    metadata is already an arbitrary-precision JSON int and must NOT
    round-trip through float64 (exact only to 2^53); legacy float
    values whose integer value is unambiguous are converted silently
    (their step boundaries are integral), while a float that is NOT
    exactly an integer — a corrupted or hand-edited hint — is rejected
    with a warning (and rounded) instead of silently rounding."""
    if isinstance(tokens_seen, int):
        return tokens_seen
    f = float(tokens_seen)
    if not f.is_integer():
        warnings.warn(
            f"legacy checkpoint tokens_seen={f!r} is not exactly "
            f"representable as an int; rounding to {int(round(f))} — "
            f"the resumed data position may be off by up to one step",
            stacklevel=2)
    elif abs(f) >= 2.0 ** 53:
        warnings.warn(
            f"legacy float tokens_seen={f!r} exceeds 2^53: the true "
            f"token count may have been rounded at save time",
            stacklevel=2)
    return int(round(f))


# --------------------------------------------------------------------- #
# phase-aware save/resume
# --------------------------------------------------------------------- #

def _plan_phase(plan, tokens_seen: int, seq_len):
    """Phase the next step belongs to — realized (step-quantized)
    boundaries when seq_len is known, matching the loader and the
    device LR; ideal token boundaries otherwise."""
    if seq_len:
        return plan.realized_phase_at(tokens_seen, seq_len)
    return plan.phase_at_tokens(tokens_seen)


def save_phase_checkpoint(path: str, params, opt_state, step: int,
                          tokens_seen: int, *, plan,
                          seq_len: Optional[int] = None,
                          extra: Optional[Dict[str, Any]] = None,
                          chunk_bytes: int = DEFAULT_CHUNK_BYTES):
    """Like :func:`save`, plus the plan position at ``tokens_seen``:
    the phase the *next* step belongs to and its batch size.
    ``tokens_seen`` is the trainer's exact host integer."""
    ph = _plan_phase(plan, tokens_seen, seq_len)
    meta = {"phase": ph.index, "batch_size": ph.batch_size,
            "schedule_kind": plan.kind,
            "total_tokens": plan.total_tokens, **(extra or {})}
    save(path, params, opt_state, step, tokens_seen, extra=meta,
         chunk_bytes=chunk_bytes)


def restore_phase_checkpoint(path: str, params_template, opt_template,
                             *, plan, seq_len: Optional[int] = None,
                             shardings: Optional[Tuple[Any, Any]] = None,
                             verify: bool = False
                             ) -> Tuple[Any, Any, Dict[str, Any]]:
    """Restore and verify the plan agrees with the checkpoint: the
    restored ``tokens_seen`` must land in the recorded phase with the
    recorded batch size, or the resumed run would silently train with
    the wrong compiled step / LR scale.  ``tokens_seen`` in the
    returned meta is an exact int for post-PR-4 checkpoints and a float
    for legacy ones (callers round — boundaries are integral)."""
    params, opt, meta = restore(path, params_template, opt_template,
                                shardings=shardings, verify=verify)
    if "phase" in meta:
        tok = exact_tokens(meta["tokens_seen"])
        ph = _plan_phase(plan, tok, seq_len)
        if (ph.index != meta["phase"]
                or ph.batch_size != meta["batch_size"]):
            raise ValueError(
                f"checkpoint was saved in phase {meta['phase']} "
                f"(batch {meta['batch_size']}) but this plan puts "
                f"tokens_seen={tok} in phase "
                f"{ph.index} (batch {ph.batch_size}) — schedule "
                f"mismatch between save and resume")
    return params, opt, meta
