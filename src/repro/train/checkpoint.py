"""Checkpointing: params/opt-state/step/tokens to a single .npz with
path-flattened keys — dependency-free, works for any pytree of arrays.

Phase-aware save/resume: ``save_phase_checkpoint`` records the plan
position (phase index, batch size, schedule kind) next to
``tokens_seen``; ``restore_phase_checkpoint`` validates that the
restoring run's plan lands the same token count in the same phase, so
the engine resumes with the correct compiled step (batch size) and the
device-side LR curve picks up exactly where it left off.

``tokens_seen`` round-trips losslessly: the trainer passes an exact
Python int and JSON preserves arbitrary-precision integers, so a
resumed run continues from the exact token count however long the run
(pre-integer float checkpoints still restore — the trainer rounds)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}[{i}]/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _unflatten_into(template, flat: Dict[str, np.ndarray], prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        vals = [_unflatten_into(v, flat, f"{prefix}[{i}]/")
                for i, v in enumerate(template)]
        return type(template)(vals)
    arr = flat[prefix.rstrip("/")]
    return jax.numpy.asarray(arr, dtype=template.dtype)


def _base(path: str) -> str:
    return path[:-4] if path.endswith(".npz") else path


def save(path: str, params, opt_state, step: int, tokens_seen: float,
         extra: Dict[str, Any] | None = None):
    base = _base(path)
    os.makedirs(os.path.dirname(base) or ".", exist_ok=True)
    flat = {}
    flat.update({f"p:{k}": v for k, v in _flatten(params).items()})
    flat.update({f"o:{k}": v for k, v in _flatten(opt_state).items()})
    np.savez(base + ".npz", **flat)
    meta = {"step": step, "tokens_seen": tokens_seen, **(extra or {})}
    with open(base + ".meta.json", "w") as f:
        json.dump(meta, f)


def restore(path: str, params_template, opt_template
            ) -> Tuple[Any, Any, Dict[str, Any]]:
    base = _base(path)
    data = np.load(base + ".npz")
    flat_p = {k[2:]: data[k] for k in data.files if k.startswith("p:")}
    flat_o = {k[2:]: data[k] for k in data.files if k.startswith("o:")}
    params = _unflatten_into(params_template, flat_p)
    opt = _unflatten_into(opt_template, flat_o)
    with open(base + ".meta.json") as f:
        meta = json.load(f)
    return params, opt, meta


# --------------------------------------------------------------------- #
# phase-aware save/resume
# --------------------------------------------------------------------- #

def _plan_phase(plan, tokens_seen: float, seq_len):
    """Phase the next step belongs to — realized (step-quantized)
    boundaries when seq_len is known, matching the loader and the
    device LR; ideal token boundaries otherwise."""
    if seq_len:
        return plan.realized_phase_at(tokens_seen, seq_len)
    return plan.phase_at_tokens(tokens_seen)


def save_phase_checkpoint(path: str, params, opt_state, step: int,
                          tokens_seen: float, *, plan,
                          seq_len: int | None = None,
                          extra: Dict[str, Any] | None = None):
    """Like :func:`save`, plus the plan position at ``tokens_seen``:
    the phase the *next* step belongs to and its batch size."""
    ph = _plan_phase(plan, tokens_seen, seq_len)
    meta = {"phase": ph.index, "batch_size": ph.batch_size,
            "schedule_kind": plan.kind,
            "total_tokens": plan.total_tokens, **(extra or {})}
    save(path, params, opt_state, step, tokens_seen, extra=meta)


def restore_phase_checkpoint(path: str, params_template, opt_template,
                             *, plan, seq_len: int | None = None
                             ) -> Tuple[Any, Any, Dict[str, Any]]:
    """Restore and verify the plan agrees with the checkpoint: the
    restored ``tokens_seen`` must land in the recorded phase with the
    recorded batch size, or the resumed run would silently train with
    the wrong compiled step / LR scale."""
    params, opt, meta = restore(path, params_template, opt_template)
    if "phase" in meta:
        ph = _plan_phase(plan, float(meta["tokens_seen"]), seq_len)
        if (ph.index != meta["phase"]
                or ph.batch_size != meta["batch_size"]):
            raise ValueError(
                f"checkpoint was saved in phase {meta['phase']} "
                f"(batch {meta['batch_size']}) but this plan puts "
                f"tokens_seen={meta['tokens_seen']:.0f} in phase "
                f"{ph.index} (batch {ph.batch_size}) — schedule "
                f"mismatch between save and resume")
    return params, opt, meta
