"""Mamba2-2.7B [arXiv:2405.21060] — SSD (state-space duality).

Attention-free SSM, 64L, d_model=2560, ssm_state=128, expand=2,
head_dim=64, vocab=50280 (padded 50304).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    max_seq_len=1_048_576,
    act="silu",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  chunk_size=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
