"""Phi-3.5-MoE (42B total / 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct].

MoE decoder, 32L, d_model=4096, 32 heads (GQA kv=8), expert d_ff=6400,
vocab=32064, 16 experts, top-2 routing.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    max_seq_len=131072,
    rope_theta=10_000.0,
    act="silu",
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=6400),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
