"""Mistral-NeMo-12B [hf:mistralai/Mistral-Nemo-Base-2407].

Dense decoder, 40L, d_model=5120, 32 heads (GQA kv=8, head_dim=128),
d_ff=14336, vocab=131072 (Tekken), 128k context, RoPE theta=1e6.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    arch_type="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    max_seq_len=131072,
    rope_theta=1_000_000.0,
    act="silu",
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)
