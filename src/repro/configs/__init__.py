"""Config registry: ``get_config('<arch-id>')`` for every assigned
architecture (exact published hyperparameters) plus the paper's own
150M/300M/600M presets."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (INPUT_SHAPES, HybridConfig, InputShape,
                                ModelConfig, MoEConfig, OptimizerConfig,
                                RunConfig, ScheduleConfig, SSMConfig)

_MODULES: Dict[str, str] = {
    "mistral-nemo-12b":        "repro.configs.mistral_nemo_12b",
    "llama3.2-3b":             "repro.configs.llama3_2_3b",
    "seamless-m4t-medium":     "repro.configs.seamless_m4t_medium",
    "recurrentgemma-9b":       "repro.configs.recurrentgemma_9b",
    "yi-34b":                  "repro.configs.yi_34b",
    "phi3.5-moe-42b-a6.6b":    "repro.configs.phi3_5_moe",
    "granite-moe-1b-a400m":    "repro.configs.granite_moe_1b",
    "internvl2-76b":           "repro.configs.internvl2_76b",
    "mamba2-2.7b":             "repro.configs.mamba2_2_7b",
    "starcoder2-3b":           "repro.configs.starcoder2_3b",
    "seesaw-150m":             "repro.configs.seesaw_paper",
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "seesaw-150m"]


def get_config(name: str) -> ModelConfig:
    if name in ("seesaw-300m", "seesaw-600m"):
        mod = importlib.import_module("repro.configs.seesaw_paper")
        return {"seesaw-300m": mod.SEESAW_300M,
                "seesaw-600m": mod.SEESAW_600M}[name]
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def list_archs() -> List[str]:
    return list(_MODULES) + ["seesaw-300m", "seesaw-600m"]


__all__ = [
    "ASSIGNED_ARCHS", "INPUT_SHAPES", "HybridConfig", "InputShape",
    "ModelConfig", "MoEConfig", "OptimizerConfig", "RunConfig",
    "ScheduleConfig", "SSMConfig", "get_config", "list_archs",
]
