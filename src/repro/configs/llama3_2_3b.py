"""Llama-3.2-3B [hf:meta-llama/Llama-3.2-1B family card].

Dense decoder, 28L, d_model=3072, 24 heads (GQA kv=8), d_ff=8192,
vocab=128256, RoPE theta=500k, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    arch_type="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    max_seq_len=131072,
    rope_theta=500_000.0,
    tie_embeddings=True,
    act="silu",
    source="hf:meta-llama/Llama-3.2-1B",
)
