"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base].

MoE decoder, 24L, d_model=1024, 16 heads (GQA kv=8), expert d_ff=512,
vocab=49155 (padded 49280), 32 experts, top-8 routing.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    max_seq_len=4096,
    rope_theta=10_000.0,
    act="silu",
    tie_embeddings=True,
    moe=MoEConfig(num_experts=32, top_k=8, d_expert=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
