"""Config system: model / schedule / run configs as frozen dataclasses.

Every assigned architecture gets one module in this package defining
``CONFIG: ModelConfig`` with the exact published hyperparameters (source
cited in the module docstring).  ``reduced()`` derives the smoke-test
variant (≤2 layers, d_model ≤ 512, ≤4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, replace
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block hyperparameters (arXiv:2405.21060)."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style hybrid (arXiv:2402.19427): pattern of
    recurrent (RG-LRU) and local-attention blocks."""
    pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")
    lru_width: Optional[int] = None      # defaults to d_model
    local_window: int = 2048
    conv1d_width: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int                # logical vocabulary
    head_dim: Optional[int] = None
    max_seq_len: int = 131072
    rope_theta: float = 500000.0
    sliding_window: Optional[int] = None     # None = full attention
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"              # silu (SwiGLU) | gelu
    # hot-path op backend: xla | pallas | pallas_interpret
    # (see repro.kernels.backend)
    kernel_backend: str = "xla"
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # encoder-decoder (audio) / vlm frontends -------------------------------
    n_encoder_layers: int = 0      # encdec only
    frontend_tokens: int = 0       # patches/frames consumed from the stub frontend
    frontend_dim: Optional[int] = None   # embedding dim emitted by the stub
    source: str = ""               # citation

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim is None and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 so the 'model' axis (16) always
        divides the embedding shard dim (TPU-friendly, see DESIGN.md §3)."""
        return _round_up(self.vocab_size, 128)

    @property
    def q_dim(self) -> int:
        return self.n_heads * (self.head_dim or 0)

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * (self.head_dim or 0)

    @property
    def is_subquadratic(self) -> bool:
        return (
            self.arch_type in ("ssm", "hybrid")
            or self.sliding_window is not None
        )

    @property
    def is_decoder(self) -> bool:
        return True  # all assigned archs have a decoder (enc-dec decodes text)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included, logical vocab)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.arch_type == "ssm":
            s = self.ssm or SSMConfig()
            di = s.d_inner(d)
            nh = s.n_ssm_heads(d)
            # in_proj (z,x,B,C,dt) + out_proj + conv + A,D
            per_layer = d * (2 * di + 2 * s.d_state + nh) + di * d \
                + s.d_conv * (di + 2 * s.d_state) + 2 * nh + 2 * d
            return emb + L * per_layer
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.act == "silu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        norms = 2 * d
        if self.arch_type == "moe":
            m = self.moe
            assert m is not None
            ff = m.num_experts * 3 * d * m.d_expert + d * m.num_experts
            per_layer = attn + ff + norms
        elif self.arch_type == "hybrid":
            h = self.hybrid or HybridConfig()
            w = h.lru_width or d
            rec = d * w * 2 + w * d + 2 * w + h.conv1d_width * w  # gates+proj+lru
            n_rec = sum(1 for p in _pattern(self, L) if p == "recurrent")
            n_att = L - n_rec
            per_layer = 0
            total = n_att * (attn + mlp + norms) + n_rec * (rec + mlp + norms)
            return emb + total
        else:
            per_layer = attn + mlp + norms
        total = L * per_layer
        if self.arch_type == "encdec":
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            total += self.n_encoder_layers * (attn + mlp + norms) + L * attn
        return emb + total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top_k experts only)."""
        if self.arch_type != "moe":
            return self.param_count()
        m = self.moe
        assert m is not None
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        ff = m.top_k * 3 * d * m.d_expert + d * m.num_experts
        return emb + L * (attn + ff + 2 * d)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=256,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=512,
            vocab_size=512,
            head_dim=64,
            max_seq_len=4096,
        )
        if self.arch_type == "moe":
            assert self.moe is not None
            kw["moe"] = replace(
                self.moe,
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                d_expert=128,
            )
        if self.arch_type == "ssm":
            kw["ssm"] = replace(self.ssm or SSMConfig(), d_state=16,
                                head_dim=64, chunk_size=32)
            kw["n_heads"] = 0
            kw["n_kv_heads"] = 0
        if self.arch_type == "hybrid":
            kw["hybrid"] = replace(self.hybrid or HybridConfig(),
                                   lru_width=256, local_window=64)
        if self.arch_type == "encdec":
            kw["n_encoder_layers"] = 2
        if self.arch_type in ("vlm", "audio", "encdec"):
            kw["frontend_tokens"] = 16
            kw["frontend_dim"] = 256
        if self.sliding_window is not None:
            kw["sliding_window"] = 64
        return replace(self, **kw)


def _pattern(cfg: ModelConfig, n_layers: int) -> Tuple[str, ...]:
    h = cfg.hybrid or HybridConfig()
    reps = math.ceil(n_layers / len(h.pattern))
    return tuple((h.pattern * reps)[:n_layers])


@dataclass(frozen=True)
class ScheduleConfig:
    """LR×batch schedule — the paper's contribution lives here."""
    kind: str = "cosine"           # cosine | step | seesaw | seesaw-general | constant | adaptive-seesaw
    base_lr: float = 3e-3
    warmup_frac: float = 0.10      # paper: warmup for 10% of tokens
    alpha: float = 2.0             # step-decay factor of the *reference* scheduler
    beta: float = 1.0              # batch multiplier per cut (seesaw: beta = alpha)
    n_cuts: int = 8                # step-decay approximation depth of cosine;
    #                                adaptive-seesaw: max cuts the controller may
    #                                fire (also sizes the runtime LR table)
    final_lr_frac: float = 0.0
    max_batch_size: Optional[int] = None   # hardware cap on the ramp
    # adaptive-seesaw controller knobs (ignored by every other kind);
    # see docs/adaptive.md
    ema_decay: float = 0.98        # device loss-EMA decay per step
    plateau_window: int = 50       # steps per plateau test
    plateau_threshold: float = 2e-3  # relative improvement floor
    plateau_min_steps: Optional[int] = None  # min steps between cuts
    #                                          (None ⇒ plateau_window)


@dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"            # adamw | adam | sgd | nsgd
    beta1: float = 0.9             # paper §4
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0      # paper default λ=0
    grad_clip: float = 1.0


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    schedule: ScheduleConfig
    optimizer: OptimizerConfig
    seq_len: int = 1024
    global_batch_size: int = 256   # B0 — sequences per step
    total_tokens: int = 0          # 0 ⇒ Chinchilla D = 20·N
    z_loss: float = 0.0
    seed: int = 0
    dtype: str = "bfloat16"        # compute dtype; params/opt state f32
    remat: bool = True
    log_every: int = 10
    # run-level kernel backend override; None keeps model.kernel_backend
    kernel_backend: Optional[str] = None

    def resolved_total_tokens(self) -> int:
        if self.total_tokens:
            return self.total_tokens
        return 20 * self.model.param_count()

    def resolved_model(self) -> ModelConfig:
        """The model config with the run-level kernel backend applied —
        what the training engine compiles against."""
        if (self.kernel_backend is not None
                and self.kernel_backend != self.model.kernel_backend):
            return replace(self.model, kernel_backend=self.kernel_backend)
        return self.model


@dataclass(frozen=True)
class InputShape:
    """One assigned workload shape."""
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    # Seesaw phase-k batch sizes (B0=256 doubled per phase) — §Perf
    # analysis shapes, not part of the assigned 40:
    "train_4k_b512":  InputShape("train_4k_b512",  4_096,  512, "train"),
    "train_4k_b1024": InputShape("train_4k_b1024", 4_096, 1024, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",  524_288,    1, "decode"),
}
