"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

Hybrid: RG-LRU recurrent blocks + local attention, 1 attention : 2
recurrent pattern, 38L, d_model=4096, 16 heads (MQA kv=1, head_dim=256),
d_ff=12288, vocab=256000, local window 2048.
"""
from repro.configs.base import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    max_seq_len=1_048_576,      # unbounded in principle; state is O(1)
    rope_theta=10_000.0,
    act="gelu",
    hybrid=HybridConfig(
        pattern=("recurrent", "recurrent", "attention"),
        lru_width=4096,
        local_window=2048,
        conv1d_width=4,
    ),
    source="arXiv:2402.19427",
)
