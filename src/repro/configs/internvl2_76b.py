"""InternVL2-Llama3-76B language backbone [arXiv:2404.16821].

VLM: InternViT-6B vision encoder + MLP projector (STUB — ``input_specs``
provides projected patch embeddings) feeding a Llama-3-70B-class decoder:
80L, d_model=8192, 64 heads (GQA kv=8), d_ff=28672, vocab=128256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    max_seq_len=32768,
    rope_theta=500_000.0,
    act="silu",
    frontend_tokens=1024,       # ViT patches per image after projector
    frontend_dim=8192,
    source="arXiv:2404.16821",
)
