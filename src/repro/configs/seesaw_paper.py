"""The paper's own model presets (§4): 150M / 300M / 600M non-embedding
parameters, OLMo-style, trained at Chinchilla scale (D = 20N) on C4 with
the T5 tokenizer (vocab 32128), seq len 1024.

Architecture tuples (depth, heads, width): 150M (12,16,1024),
300M (24,16,1024), 600M (24,22,1408).  CBS per §4: 256k / 512k / 1024k
tokens, i.e. B* = 256 / 512 / 1024 sequences at L=1024.
"""
from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                ScheduleConfig)


def _olmo_like(name: str, depth: int, heads: int, width: int) -> ModelConfig:
    return ModelConfig(
        name=name,
        arch_type="dense",
        n_layers=depth,
        d_model=width,
        n_heads=heads,
        n_kv_heads=heads,           # MHA at these scales
        head_dim=width // heads,
        d_ff=4 * width,
        vocab_size=32128,           # T5 tokenizer
        max_seq_len=1024,
        rope_theta=10_000.0,
        act="silu",
        source="Seesaw paper §4 (OLMo codebase)",
    )


SEESAW_150M = _olmo_like("seesaw-150m", 12, 16, 1024)
SEESAW_300M = _olmo_like("seesaw-300m", 24, 16, 1024)
SEESAW_600M = _olmo_like("seesaw-600m", 24, 22, 1408)

# Critical batch sizes from §4 (in sequences at L=1024).
CBS = {"seesaw-150m": 256, "seesaw-300m": 512, "seesaw-600m": 1024}

CONFIG = SEESAW_150M   # default --arch seesaw-150m target


def paper_run(model: ModelConfig, *, kind: str = "seesaw",
              batch_size: int | None = None, lr: float = 3e-3,
              alpha: float = 2.0) -> RunConfig:
    """A RunConfig matching the paper's §4 protocol."""
    bs = batch_size or CBS.get(model.name, 256)
    beta = alpha if kind == "seesaw" else 1.0
    return RunConfig(
        model=model,
        schedule=ScheduleConfig(kind=kind, base_lr=lr, warmup_frac=0.10,
                                alpha=alpha, beta=beta),
        optimizer=OptimizerConfig(kind="adamw", beta1=0.9, beta2=0.95,
                                  eps=1e-8, weight_decay=0.0),
        seq_len=1024,
        global_batch_size=bs,
        z_loss=0.0,
    )
