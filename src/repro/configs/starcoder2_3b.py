"""StarCoder2-3B [arXiv:2402.19173].

Dense decoder, 30L, d_model=3072, 24 heads (GQA kv=2), d_ff=12288,
vocab=49152, RoPE, native 4096-token sliding-window attention
(⇒ runs the long_500k decode shape sub-quadratically).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    max_seq_len=1_048_576,
    rope_theta=999_999.4,
    sliding_window=4096,
    act="gelu",
    source="arXiv:2402.19173",
)
