"""SeamlessM4T-medium text backbone [arXiv:2308.11596].

Encoder-decoder transformer, 12L each, d_model=1024, 16 heads (kv=16,
i.e. MHA), d_ff=4096, vocab=256206 (padded to 256256 for the model axis).
The speech frontend (mel + conv w2v-BERT feature extractor) is a STUB —
``input_specs`` provides precomputed frame embeddings (B, frames, 1024).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    n_layers=12,                # decoder layers
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    max_seq_len=32768,
    rope_theta=10_000.0,
    act="gelu",
    frontend_tokens=1024,       # audio frames consumed per example
    frontend_dim=1024,
    source="arXiv:2308.11596",
)
