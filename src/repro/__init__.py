"""repro — production-grade JAX reproduction of "Seesaw: Accelerating
Training by Balancing Learning Rate and Batch Size Scheduling"."""
__version__ = "1.0.0"
