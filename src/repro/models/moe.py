"""Top-k mixture-of-experts FFN with capacity-based einsum dispatch.

Experts are sharded over the 'model' mesh axis (16 experts → 1/chip on
phi3.5; 32 → 2/chip on granite); the dispatch/combine einsums lower to
all-to-alls under SPMD.  Aux losses: switch-style load balance + router
z-loss.  Capacity is computed from the *per-group* token count so Seesaw
batch ramps re-shape dispatch tensors consistently phase over phase.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P  # noqa: F401

from repro.configs.base import ModelConfig
from repro.models.layers import constrain, dense_init, trunc_normal

Params = Dict[str, Any]


def init_moe(key, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> Params:
    m = cfg.moe
    assert m is not None
    kr, kg, ku, kd = jax.random.split(key, 4)
    d, E, dff = cfg.d_model, m.num_experts, m.d_expert
    out_std = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "router": trunc_normal(kr, (*stack, d, E), std=0.02),
        "w_gate": dense_init(kg, d, dff, std=0.02, stack=(*stack, E)),
        "w_up": dense_init(ku, d, dff, std=0.02, stack=(*stack, E)),
        "w_down": dense_init(kd, dff, d, std=out_std, stack=(*stack, E)),
    }


def moe_specs(fsdp, lead: Tuple = ()) -> Params:
    return {
        "router": P(*lead, None, None),
        "w_gate": P(*lead, "model", fsdp, None),
        "w_up": P(*lead, "model", fsdp, None),
        "w_down": P(*lead, "model", None, fsdp),
    }


def moe_forward(params: Params, x, cfg: ModelConfig, *,
                group_size: int = 2048, batch_axes=None):
    """x: (B, S, d) → (y, aux) where aux = {lb_loss, rz_loss, ...}.

    Tokens are processed in groups of ``group_size`` (capacity is per
    group), the standard TPU MoE formulation (GShard/Switch).

    ``batch_axes``: mesh axes the token/group dim is sharded over.  The
    (B,S,d)→(G,g,d) reshape defeats XLA's sharding propagation, which
    then *replicates* the dispatch one-hots — observed as 6.6 GB of
    all-gather per layer on granite-moe (EXPERIMENTS.md §Perf B1).  The
    constraints below pin groups to the data axis and experts to the
    model axis, so dispatch/combine lower to all-to-alls.
    """
    m = cfg.moe
    assert m is not None
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    tokens = B * S
    g = min(group_size, tokens)
    n_groups = tokens // g
    assert n_groups * g == tokens, (tokens, g)
    cap = int(math.ceil(g * k * m.capacity_factor / E))
    cap = min(max(cap, k), g)   # an expert can receive at most g tokens

    xt = x.reshape(n_groups, g, d)
    if batch_axes is not None:
        xt = constrain(xt, P(batch_axes, None, None))
    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)               # (G,g,E)

    # --- top-k gating with per-expert position assignment ---------------
    gate_vals, gate_idx = jax.lax.top_k(probs, k)         # (G,g,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (G,g,k,E)
    # position of each (token, choice) within its expert's queue:
    flat = onehot.reshape(n_groups, g * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                 # (G,g*k,E)
    pos = pos.reshape(n_groups, g, k, E)
    in_cap = (pos < cap) & (onehot > 0)
    pos_cap = jnp.einsum("Gske,Gske->Gsk", pos, onehot).astype(jnp.int32)
    keep = jnp.any(in_cap, axis=-1)                       # (G,g,k)

    # dispatch: (G, g, E, C) one-hot combine weights
    pos_onehot = jax.nn.one_hot(pos_cap, cap, dtype=jnp.float32)  # (G,g,k,C)
    combine = jnp.einsum("Gsk,Gske,Gskc->Gsec",
                         gate_vals * keep, onehot, pos_onehot)
    combine = combine.astype(x.dtype)                     # bf16 on the wire
    dispatch = (combine > 0).astype(x.dtype)              # (G,g,E,C)
    if batch_axes is not None:
        # shard the E dim over 'model': the expert contraction then
        # keeps dispatch/combine local to each expert shard (partial-sum
        # + all-reduce on the small (G,g,d) output) instead of
        # all-gathering 5.4 GB of f32 one-hots per layer
        combine = constrain(combine, P(batch_axes, None, "model", None))
        dispatch = constrain(dispatch, P(batch_axes, None, "model", None))

    # --- expert computation (all-to-all under expert sharding) ----------
    ex_in = jnp.einsum("Gsec,Gsd->eGcd", dispatch, xt)    # (E,G,C,d)
    if batch_axes is not None:
        ex_in = constrain(ex_in, P("model", batch_axes, None, None))
    wg = params["w_gate"].astype(x.dtype)
    wu = params["w_up"].astype(x.dtype)
    wd = params["w_down"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("eGcd,edf->eGcf", ex_in, wg)) \
        * jnp.einsum("eGcd,edf->eGcf", ex_in, wu)
    ex_out = jnp.einsum("eGcf,efd->eGcd", h, wd)          # (E,G,C,d)
    if batch_axes is not None:
        ex_out = constrain(ex_out, P("model", batch_axes, None, None))
    y = jnp.einsum("Gsec,eGcd->Gsd", combine, ex_out)
    if batch_axes is not None:
        y = constrain(y, P(batch_axes, None, None))

    # --- aux losses ------------------------------------------------------
    # load balance (Switch): E * mean_e(frac_tokens_e * mean_prob_e)
    me = jnp.mean(probs, axis=1)                          # (G,E)
    top1 = jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(top1, axis=1)                           # (G,E)
    lb_loss = E * jnp.mean(jnp.sum(me * ce, axis=-1))
    rz = jax.nn.logsumexp(logits, axis=-1)
    rz_loss = jnp.mean(jnp.square(rz))
    frac_dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))

    aux = {"lb_loss": lb_loss, "rz_loss": rz_loss,
           "frac_dropped": frac_dropped}
    return y.reshape(B, S, d), aux


def moe_aux_total(aux: Params, cfg: ModelConfig):
    m = cfg.moe
    assert m is not None
    return (m.load_balance_loss * aux["lb_loss"]
            + m.router_z_loss * aux["rz_loss"])
