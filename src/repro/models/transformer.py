"""Dense / MoE / multimodal-prefix decoder-only transformer.

Layers are stacked (leading L dim) and executed with ``lax.scan`` so the
HLO stays one-layer-sized regardless of depth; ``jax.checkpoint`` wraps
the scanned body for training (remat).  Supports:

- GQA + RoPE + optional sliding window (starcoder2)
- MoE FFN (phi3.5, granite) with aux losses accumulated through the scan
- multimodal prefix embeddings (internvl2 VLM / seamless audio-as-prefix
  is handled by encdec.py; VLM uses this module)
- serve: ``prefill`` (build KV cache) and ``decode_step`` (one token)
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import moe as M
from repro.models.layers import (Params, constrain, cross_entropy_chunked,
                                 embed_specs, fsdp_axis, init_embed,
                                 init_mlp, mlp, mlp_specs, residual_spec,
                                 rmsnorm)


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #

def init_layer_stack(key, cfg: ModelConfig) -> Params:
    L = cfg.n_layers
    ka, km, kn = jax.random.split(key, 3)
    p: Params = {
        "attn": A.init_attention(ka, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.head_dim,
                                 cfg.n_layers, stack=(L,)),
        "norm1": jnp.zeros((L, cfg.d_model)),
        "norm2": jnp.zeros((L, cfg.d_model)),
    }
    if cfg.arch_type == "moe":
        p["moe"] = M.init_moe(km, cfg, stack=(L,))
    else:
        p["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, cfg.act,
                            cfg.n_layers, stack=(L,))
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": init_embed(k1, cfg.padded_vocab, cfg.d_model,
                            cfg.tie_embeddings),
        "layers": init_layer_stack(k2, cfg),
        "final_norm": jnp.zeros((cfg.d_model,)),
    }


def param_specs(cfg: ModelConfig, multi_pod: bool = False) -> Params:
    f = fsdp_axis(multi_pod)
    layers = {
        "attn": A.attention_specs(f, lead=(None,)),
        "norm1": P(None, None),
        "norm2": P(None, None),
    }
    if cfg.arch_type == "moe":
        layers["moe"] = M.moe_specs(f, lead=(None,))
    else:
        layers["mlp"] = mlp_specs(cfg.act, f, lead=(None,))
    return {
        "embed": embed_specs(cfg.tie_embeddings, f),
        "layers": layers,
        "final_norm": P(None),
    }


# --------------------------------------------------------------------- #
# forward (training / prefill trunk)
# --------------------------------------------------------------------- #

def _layer(pl: Params, x, cfg: ModelConfig, *, res_spec,
           block_skip: bool = False, chunk: int = 1024):
    batch_axes = res_spec[0] if isinstance(res_spec, P) else None
    kb = cfg.kernel_backend
    h = rmsnorm(x, pl["norm1"], cfg.norm_eps, backend=kb)
    a, _ = A.attn_forward(pl["attn"], h, n_heads=cfg.n_heads,
                          n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                          rope_theta=cfg.rope_theta, causal=True,
                          window=cfg.sliding_window, chunk=chunk,
                          block_skip=block_skip, backend=kb)
    x = x + a
    x = constrain(x, res_spec)
    h = rmsnorm(x, pl["norm2"], cfg.norm_eps, backend=kb)
    aux = {}
    if cfg.arch_type == "moe":
        f, aux = M.moe_forward(pl["moe"], h, cfg, batch_axes=batch_axes)
    else:
        # sub-layer remat: recompute the MLP separately from attention in
        # backward so the peak live set is max(attn, mlp) interiors, not
        # their sum (internvl2-76b: (B,S,28672) gate/up/act tensors)
        f = jax.checkpoint(lambda hh, pm: mlp(pm, hh, cfg.act))(
            h, pl["mlp"])
    x = x + f
    x = constrain(x, res_spec)
    return x, aux


def forward_hidden(params: Params, cfg: ModelConfig, tokens, *,
                   prefix_emb=None, dtype=jnp.bfloat16, remat: bool = True,
                   multi_pod: bool = False, block_skip: bool = False,
                   attn_chunk: int = 1024, seq_shard: bool = True,
                   remat_policy: str = ""):
    """tokens: (B, S_text) int32 → final hidden states (B, S, d) where
    S = prefix + S_text.  prefix_emb: (B, S_prefix, d) from the frontend
    stub (VLM patches)."""
    batch_spec = fsdp_axis(multi_pod)
    emb = params["embed"]["tok"].astype(dtype)
    x = emb[tokens]
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(dtype), x], axis=1)
    res_spec = (residual_spec(batch_spec, x.shape[1]) if seq_shard
                else P(batch_spec, None, None))
    x = constrain(x, res_spec)

    def body(x, pl):
        y, aux = _layer(pl, x, cfg, res_spec=res_spec,
                        block_skip=block_skip, chunk=attn_chunk)
        aux = {k: jnp.asarray(v, jnp.float32) for k, v in aux.items()}
        return y, aux

    if remat:
        if remat_policy == "dots":
            # save matmul outputs, recompute elementwise only — trades
            # saved-activation HBM for a ~25% cut of recompute FLOPs
            pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            body = jax.checkpoint(body, policy=pol)
        else:
            body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps,
                backend=cfg.kernel_backend)
    aux = {k: jnp.mean(v) for k, v in auxs.items()} if auxs else {}
    return x, aux


def loss_fn(params: Params, cfg: ModelConfig, batch: Params, *,
            z_loss: float = 0.0, dtype=jnp.bfloat16, remat: bool = True,
            multi_pod: bool = False, block_skip: bool = False,
            seq_shard: bool = True, remat_policy: str = ""):
    """batch: tokens (B,S_text), labels (B,S_text), optional prefix_emb.
    Returns (loss, metrics)."""
    tokens = batch["tokens"]
    prefix = batch.get("prefix_emb")
    h, aux = forward_hidden(params, cfg, tokens, prefix_emb=prefix,
                            dtype=dtype, remat=remat, multi_pod=multi_pod,
                            block_skip=block_skip, seq_shard=seq_shard,
                            remat_policy=remat_policy)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones(labels.shape, jnp.float32))
    h = constrain(h, P(fsdp_axis(multi_pod), None, None))
    if prefix is not None:                      # loss only on text tokens
        h = h[:, prefix.shape[1]:]
    loss, z_sq = cross_entropy_chunked(
        h, params["embed"], labels, mask, cfg.vocab_size, z_loss=z_loss,
        logits_spec=P(fsdp_axis(multi_pod), None, "model"))
    metrics = {"ce_loss": loss, "z_sq": z_sq}
    if cfg.arch_type == "moe":
        loss = loss + M.moe_aux_total(aux, cfg)
        metrics.update(aux)
    metrics["loss"] = loss
    return loss, metrics


# --------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------- #

def _cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> Params:
    L = cfg.n_layers
    if cfg.sliding_window is not None:
        W = min(cfg.sliding_window, max_len)
        return {
            "k": jnp.zeros((L, batch, W, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
            "v": jnp.zeros((L, batch, W, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
            "pos": jnp.full((L, W), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                       dtype),
    }


def logits_from_hidden(params: Params, cfg: ModelConfig, h):
    W = params["embed"].get("lm_head")
    if W is None:
        W = params["embed"]["tok"].T
    logits = (h @ W.astype(h.dtype)).astype(jnp.float32)
    return logits


def prefill(params: Params, cfg: ModelConfig, tokens, *, prefix_emb=None,
            cache_len_cap: int, dtype=jnp.bfloat16, multi_pod: bool = False,
            attn_chunk: int = 1024, seq_shard: bool = True):
    """Run the prompt, return (last-token logits, kv cache, length)."""
    batch_spec = fsdp_axis(multi_pod)
    emb = params["embed"]["tok"].astype(dtype)
    x = emb[tokens]
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(dtype), x], axis=1)
    B, S, _ = x.shape
    # sequence-parallel prefill: TP partial sums lower to reduce-scatter
    # + bf16 gather instead of full-width f32 all-reduce per layer
    res_spec = (residual_spec(batch_spec, S) if seq_shard
                else P(batch_spec, None, None))
    x = constrain(x, res_spec)

    def body(x, pl):
        h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
        a, (k, v) = A.attn_forward(
            pl["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta, causal=True,
            window=cfg.sliding_window, chunk=attn_chunk)
        x = x + a
        h = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        if cfg.arch_type == "moe":
            f, _ = M.moe_forward(pl["moe"], h, cfg,
                                 batch_axes=batch_spec)
        else:
            f = mlp(pl["mlp"], h, cfg.act)
        x = constrain(x + f, res_spec)
        if cfg.sliding_window is not None:
            W = min(cfg.sliding_window, cache_len_cap)
            return x, A.ring_from_prefill(k, v, S, W, dtype=dtype)
        pad = cache_len_cap - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, {"k": k, "v": v}

    x, cache = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x[:, -1:])
    return logits, cache, jnp.asarray(S, jnp.int32)


def prefill_ragged(params: Params, cfg: ModelConfig, tokens, lengths, *,
                   prefix_emb=None, dtype=jnp.bfloat16,
                   multi_pod: bool = False, attn_chunk: int = 1024,
                   seq_shard: bool = True):
    """Bucketed prefill: tokens (B, S_bucket) right-padded to a shared
    bucket length, lengths (B,) true lengths (frontend prefix included).
    Causality makes every real position independent of the padding rows,
    so one executable serves every prompt length in the bucket.

    Returns (logits (B, 1, V) at each request's last real token,
    k, v (L, B, S, Hkv, hd)) — the raw per-layer K/V, unpadded; rows at
    positions >= lengths[b] hold padding-token junk the cache layer must
    mask (the dense cache masks by ``kv_len``, the page pool by the
    causal reach)."""
    if cfg.sliding_window is not None:
        raise NotImplementedError(
            "ragged bucketed prefill supports full attention only; "
            "sliding-window (ring-cache) archs keep the exact-length "
            "prefill path")
    batch_spec = fsdp_axis(multi_pod)
    emb = params["embed"]["tok"].astype(dtype)
    x = emb[tokens]
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(dtype), x], axis=1)
    B, S, d = x.shape
    res_spec = (residual_spec(batch_spec, S) if seq_shard
                else P(batch_spec, None, None))
    x = constrain(x, res_spec)

    def body(x, pl):
        h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
        a, (k, v) = A.attn_forward(
            pl["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta, causal=True,
            window=None, chunk=attn_chunk)
        x = x + a
        h = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        if cfg.arch_type == "moe":
            f, _ = M.moe_forward(pl["moe"], h, cfg, batch_axes=batch_spec)
        else:
            f = mlp(pl["mlp"], h, cfg.act)
        x = constrain(x + f, res_spec)
        return x, (k, v)

    x, (k, v) = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    idx = jnp.clip(lengths - 1, 0, S - 1)[:, None, None]
    h_last = jnp.take_along_axis(x, jnp.broadcast_to(idx, (B, 1, d)),
                                 axis=1)
    logits = logits_from_hidden(params, cfg, h_last)
    return logits, k, v


def decode_step(params: Params, cfg: ModelConfig, cache: Params, cache_len,
                token, *, dtype=jnp.bfloat16, multi_pod: bool = False,
                attn_chunk: int = 4096):
    """One decode step.  token: (B, 1) int32; cache from ``prefill`` /
    ``_cache_struct`` (layer-stacked).  Returns (logits, cache, len+1)."""
    batch_spec = fsdp_axis(multi_pod)
    emb = params["embed"]["tok"].astype(dtype)
    x = emb[token]
    x = constrain(x, P(batch_spec, None, None))

    def body(x, xs):
        pl, cl = xs
        h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
        a, new_cl = A.decode_attn(
            pl["attn"], h, cl, cache_len, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, window=cfg.sliding_window,
            chunk=attn_chunk)
        x = x + a
        h = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        if cfg.arch_type == "moe":
            f, _ = M.moe_forward(pl["moe"], h, cfg)
        else:
            f = mlp(pl["mlp"], h, cfg.act)
        return x + f, new_cl

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x)
    return logits, new_cache, cache_len + 1
