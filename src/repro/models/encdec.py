"""Encoder-decoder transformer backbone for seamless-m4t-medium
(arXiv:2308.11596).  The speech frontend (mel + conv feature extractor)
is a STUB per the brief: the encoder consumes precomputed frame
embeddings (B, frames, d_model) supplied by ``input_specs``.

Encoder: bidirectional self-attention layers (scanned).
Decoder: causal self-attn + cross-attn + MLP (scanned).
Serve: cross-attention K/V precomputed at prefill; decode is one-token.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models.layers import (Params, constrain, cross_entropy_chunked,
                                 embed_specs, fsdp_axis, init_embed,
                                 init_mlp, mlp, mlp_specs, residual_spec,
                                 rmsnorm)
from repro.models.transformer import logits_from_hidden


def init_params(key, cfg: ModelConfig) -> Params:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    Le, Ld = cfg.n_encoder_layers, cfg.n_layers
    d = cfg.d_model
    enc = {
        "attn": A.init_attention(k2, d, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim, Le, stack=(Le,)),
        "mlp": init_mlp(k3, d, cfg.d_ff, cfg.act, Le, stack=(Le,)),
        "norm1": jnp.zeros((Le, d)),
        "norm2": jnp.zeros((Le, d)),
    }
    kx, ky = jax.random.split(k4)
    dec = {
        "self_attn": A.init_attention(kx, d, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.head_dim, Ld, stack=(Ld,)),
        "cross_attn": A.init_attention(ky, d, cfg.n_heads, cfg.n_kv_heads,
                                       cfg.head_dim, Ld, stack=(Ld,)),
        "mlp": init_mlp(k5, d, cfg.d_ff, cfg.act, Ld, stack=(Ld,)),
        "norm1": jnp.zeros((Ld, d)),
        "norm2": jnp.zeros((Ld, d)),
        "norm3": jnp.zeros((Ld, d)),
    }
    return {
        "embed": init_embed(k1, cfg.padded_vocab, d, cfg.tie_embeddings),
        "encoder": enc,
        "decoder": dec,
        "enc_norm": jnp.zeros((d,)),
        "final_norm": jnp.zeros((d,)),
    }


def param_specs(cfg: ModelConfig, multi_pod: bool = False) -> Params:
    f = fsdp_axis(multi_pod)
    enc = {"attn": A.attention_specs(f, lead=(None,)),
           "mlp": mlp_specs(cfg.act, f, lead=(None,)),
           "norm1": P(None, None), "norm2": P(None, None)}
    dec = {"self_attn": A.attention_specs(f, lead=(None,)),
           "cross_attn": A.attention_specs(f, lead=(None,)),
           "mlp": mlp_specs(cfg.act, f, lead=(None,)),
           "norm1": P(None, None), "norm2": P(None, None),
           "norm3": P(None, None)}
    return {"embed": embed_specs(cfg.tie_embeddings, f),
            "encoder": enc, "decoder": dec,
            "enc_norm": P(None), "final_norm": P(None)}


def _cross_attend(pa: Params, h, enc_k, enc_v, cfg: ModelConfig,
                  chunk=1024):
    """h: (B,Sq,d); enc_k/enc_v: (B,Se,Hkv,hd) precomputed."""
    B, Sq, _ = h.shape
    q = (h @ pa["w_q"].astype(h.dtype)).reshape(B, Sq, cfg.n_heads,
                                                cfg.head_dim)
    o = A.chunked_attention(q, enc_k.astype(h.dtype),
                            enc_v.astype(h.dtype), causal=False,
                            chunk=chunk)
    o = o.reshape(B, Sq, cfg.n_heads * cfg.head_dim)
    return o @ pa["w_o"].astype(h.dtype)


def _enc_kv(pa: Params, enc_out, cfg: ModelConfig):
    B, Se, _ = enc_out.shape
    k = (enc_out @ pa["w_k"].astype(enc_out.dtype)) \
        .reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ pa["w_v"].astype(enc_out.dtype)) \
        .reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def encode(params: Params, cfg: ModelConfig, src_emb, *, batch_spec,
           remat=True, attn_chunk=1024, seq_shard=True):
    res_spec = (residual_spec(batch_spec, src_emb.shape[1]) if seq_shard
                else P(batch_spec, None, None))
    x = constrain(src_emb, res_spec)

    def body(x, pl):
        h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
        a, _ = A.attn_forward(pl["attn"], h, n_heads=cfg.n_heads,
                              n_kv_heads=cfg.n_kv_heads,
                              head_dim=cfg.head_dim,
                              rope_theta=cfg.rope_theta, causal=False,
                              chunk=attn_chunk)
        x = x + a
        h = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        x = constrain(x + mlp(pl["mlp"], h, cfg.act), res_spec)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    x = constrain(x, P(batch_spec, None, None))
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def decode_trunk(params: Params, cfg: ModelConfig, tokens, enc_out, *,
                 batch_spec, dtype, remat=True, attn_chunk=1024,
                 seq_shard=True):
    x = params["embed"]["tok"].astype(dtype)[tokens]
    res_spec = (residual_spec(batch_spec, x.shape[1]) if seq_shard
                else P(batch_spec, None, None))
    x = constrain(x, res_spec)

    def body(x, pl):
        h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
        a, _ = A.attn_forward(pl["self_attn"], h, n_heads=cfg.n_heads,
                              n_kv_heads=cfg.n_kv_heads,
                              head_dim=cfg.head_dim,
                              rope_theta=cfg.rope_theta, causal=True,
                              chunk=attn_chunk)
        x = x + a
        h = rmsnorm(x, pl["norm3"], cfg.norm_eps)
        ek, ev = _enc_kv(pl["cross_attn"], enc_out, cfg)
        x = x + _cross_attend(pl["cross_attn"], h, ek, ev, cfg,
                              chunk=attn_chunk)
        h = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        x = constrain(x + mlp(pl["mlp"], h, cfg.act), res_spec)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = constrain(x, P(batch_spec, None, None))
    return rmsnorm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(params, cfg, batch, *, z_loss=0.0, dtype=jnp.bfloat16,
            remat=True, multi_pod=False, **_):
    """batch: src_emb (B,Se,d) frontend stub output, tokens (B,St),
    labels (B,St)."""
    batch_spec = fsdp_axis(multi_pod)
    enc_out = encode(params, cfg, batch["src_emb"].astype(dtype),
                     batch_spec=batch_spec, remat=remat)
    h = decode_trunk(params, cfg, batch["tokens"], enc_out,
                     batch_spec=batch_spec, dtype=dtype, remat=remat)
    mask = batch.get("mask", jnp.ones(batch["labels"].shape, jnp.float32))
    loss, z_sq = cross_entropy_chunked(
        h, params["embed"], batch["labels"], mask, cfg.vocab_size,
        z_loss=z_loss,
        logits_spec=P(fsdp_axis(multi_pod), None, "model"))
    return loss, {"ce_loss": loss, "z_sq": z_sq, "loss": loss}


def forward_hidden(params, cfg, tokens, *, prefix_emb=None,
                   dtype=jnp.bfloat16, remat=True, multi_pod=False, **_):
    batch_spec = fsdp_axis(multi_pod)
    assert prefix_emb is not None, "encdec needs src embeddings"
    enc_out = encode(params, cfg, prefix_emb.astype(dtype),
                     batch_spec=batch_spec, remat=remat)
    h = decode_trunk(params, cfg, tokens, enc_out, batch_spec=batch_spec,
                     dtype=dtype, remat=remat)
    return h, {}


def _cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    L = cfg.n_layers
    Se = cfg.frontend_tokens
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                       dtype),
        "ek": jnp.zeros((L, batch, Se, cfg.n_kv_heads, cfg.head_dim),
                        dtype),
        "ev": jnp.zeros((L, batch, Se, cfg.n_kv_heads, cfg.head_dim),
                        dtype),
    }


def prefill(params, cfg, tokens, *, prefix_emb=None, cache_len_cap: int,
            dtype=jnp.bfloat16, multi_pod=False, attn_chunk=1024, **_):
    batch_spec = fsdp_axis(multi_pod)
    assert prefix_emb is not None
    enc_out = encode(params, cfg, prefix_emb.astype(dtype),
                     batch_spec=batch_spec, remat=False,
                     attn_chunk=attn_chunk)
    x = params["embed"]["tok"].astype(dtype)[tokens]
    B, S, _ = x.shape
    res_spec = residual_spec(batch_spec, S)
    x = constrain(x, res_spec)

    def body(x, pl):
        h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
        a, (k, v) = A.attn_forward(pl["self_attn"], h, n_heads=cfg.n_heads,
                                   n_kv_heads=cfg.n_kv_heads,
                                   head_dim=cfg.head_dim,
                                   rope_theta=cfg.rope_theta, causal=True,
                                   chunk=attn_chunk)
        x = x + a
        h = rmsnorm(x, pl["norm3"], cfg.norm_eps)
        ek, ev = _enc_kv(pl["cross_attn"], enc_out, cfg)
        x = x + _cross_attend(pl["cross_attn"], h, ek, ev, cfg,
                              chunk=attn_chunk)
        h = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        x = constrain(x + mlp(pl["mlp"], h, cfg.act), res_spec)
        pad = cache_len_cap - S
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, {"k": kp.astype(dtype), "v": vp.astype(dtype),
                   "ek": ek.astype(dtype), "ev": ev.astype(dtype)}

    x, cache = jax.lax.scan(body, x, params["decoder"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return logits_from_hidden(params, cfg, x[:, -1:]), cache, \
        jnp.asarray(S, jnp.int32)


def decode_step(params, cfg, cache, cache_len, token, *,
                dtype=jnp.bfloat16, multi_pod=False, attn_chunk=4096, **_):
    batch_spec = fsdp_axis(multi_pod)
    x = params["embed"]["tok"].astype(dtype)[token]
    x = constrain(x, P(batch_spec, None, None))

    def body(x, xs):
        pl, cl = xs
        h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
        a, new_kv = A.decode_attn(pl["self_attn"], h,
                                  {"k": cl["k"], "v": cl["v"]}, cache_len,
                                  n_heads=cfg.n_heads,
                                  n_kv_heads=cfg.n_kv_heads,
                                  head_dim=cfg.head_dim,
                                  rope_theta=cfg.rope_theta,
                                  chunk=attn_chunk)
        x = x + a
        h = rmsnorm(x, pl["norm3"], cfg.norm_eps)
        x = x + _cross_attend(pl["cross_attn"], h, cl["ek"], cl["ev"], cfg)
        h = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        x = x + mlp(pl["mlp"], h, cfg.act)
        return x, {**new_kv, "ek": cl["ek"], "ev": cl["ev"]}

    x, new_cache = jax.lax.scan(body, x, (params["decoder"], cache))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), new_cache, cache_len + 1
