"""Mamba-2 SSD (state-space duality, arXiv:2405.21060) in JAX.

Training/prefill uses the chunked SSD algorithm: the sequence is split
into chunks of Q tokens; within a chunk the recurrence is computed as a
masked quadratic form (MXU-friendly batched matmuls), across chunks a
small carried state (H, P, N) is scanned.  Decode is the O(1) recurrent
step.  The pure-jnp reference recurrence lives in kernels/ref.py; the
Pallas kernel tiles (chunk × head) blocks into VMEM.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, SSMConfig
from repro.kernels import backend as KB
from repro.models.layers import (Params, constrain, cross_entropy_chunked,
                                 dense_init, embed_specs, fsdp_axis,
                                 init_embed, residual_spec, rmsnorm,
                                 trunc_normal)
from repro.models.transformer import logits_from_hidden


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #

def _dims(cfg: ModelConfig):
    s = cfg.ssm or SSMConfig()
    di = s.d_inner(cfg.d_model)
    H = s.n_ssm_heads(cfg.d_model)
    return s, di, H, s.head_dim, s.d_state


def init_mixer(key, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> Params:
    s, di, H, Pdim, N = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    out_std = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    dt = jnp.exp(jnp.linspace(math.log(1e-3), math.log(1e-1), H))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))        # inverse softplus
    return {
        "w_z": dense_init(ks[0], d, di, std=0.02, stack=stack),
        "w_x": dense_init(ks[1], d, di, std=0.02, stack=stack),
        "w_B": dense_init(ks[2], d, N, std=0.02, stack=stack),
        "w_C": dense_init(ks[3], d, N, std=0.02, stack=stack),
        "w_dt": dense_init(ks[4], d, H, std=0.02, stack=stack),
        "dt_bias": jnp.broadcast_to(dt_bias, (*stack, H)),
        "A_log": jnp.broadcast_to(jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
                                  (*stack, H)),
        "D": jnp.ones((*stack, H)),
        "conv_w": trunc_normal(ks[5], (*stack, s.d_conv, di + 2 * N),
                               std=0.2),
        "conv_b": jnp.zeros((*stack, di + 2 * N)),
        "norm": jnp.zeros((*stack, di)),
        "w_out": dense_init(ks[6], di, d, std=out_std, stack=stack),
    }


def mixer_specs(fsdp, lead: Tuple = ()) -> Params:
    return {
        "w_z": P(*lead, fsdp, "model"),
        "w_x": P(*lead, fsdp, "model"),
        "w_B": P(*lead, fsdp, None),
        "w_C": P(*lead, fsdp, None),
        "w_dt": P(*lead, fsdp, None),
        "dt_bias": P(*lead, None),
        "A_log": P(*lead, None),
        "D": P(*lead, None),
        "conv_w": P(*lead, None, "model"),
        "conv_b": P(*lead, "model"),
        "norm": P(*lead, "model"),
        "w_out": P(*lead, "model", fsdp),
    }


def init_params(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "embed": init_embed(k1, cfg.padded_vocab, cfg.d_model,
                            cfg.tie_embeddings),
        "layers": {
            "mixer": init_mixer(k2, cfg, stack=(cfg.n_layers,)),
            "norm": jnp.zeros((cfg.n_layers, cfg.d_model)),
        },
        "final_norm": jnp.zeros((cfg.d_model,)),
    }


def param_specs(cfg: ModelConfig, multi_pod: bool = False) -> Params:
    f = fsdp_axis(multi_pod)
    return {
        "embed": embed_specs(cfg.tie_embeddings, f),
        "layers": {"mixer": mixer_specs(f, lead=(None,)),
                   "norm": P(None, None)},
        "final_norm": P(None),
    }


# --------------------------------------------------------------------- #
# conv helper
# --------------------------------------------------------------------- #

def causal_conv1d(x, w, b):
    """Depthwise causal conv.  x: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(K))
    return out + b.astype(x.dtype)


# --------------------------------------------------------------------- #
# chunked SSD scan
# --------------------------------------------------------------------- #

def ssd_chunked(xh, dt, A, Bm, Cm, D, *, chunk: int, h0=None):
    """Chunked SSD.

    xh: (B,S,H,P) values; dt: (B,S,H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B,S,N) (single group shared across heads); D: (H,).
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:  # pad with dt=0 steps: a=1 (state carried), zero contribution
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    xh32 = xh.astype(jnp.float32)
    l = dt.astype(jnp.float32) * A                       # (B,S,H) log-decay
    xc = xh32.reshape(Bsz, nc, Q, H, Pd)
    dtc = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    lc = l.reshape(Bsz, nc, Q, H)
    Bc = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Cc = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, N)

    cum = jnp.cumsum(lc, axis=2)                          # (B,nc,Q,H)
    T = cum[:, :, -1]                                     # (B,nc,H)

    # intra-chunk quadratic part
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)            # (B,nc,Q,Q)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H) i,j
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    M = CB[..., None] * decay * dtc[:, :, None, :, :]     # (B,nc,i,j,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xc)

    # chunk-final states: S_c = sum_j exp(T - cum_j) dt_j B_j ⊗ x_j
    sdecay = jnp.exp(T[:, :, None] - cum) * dtc           # (B,nc,Q,H)
    Sc = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", sdecay, Bc, xc)

    # scan across chunks
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, Pd, N), jnp.float32)

    def body(h, xs):
        Sc_c, T_c = xs
        h_prev = h
        h = h * jnp.exp(T_c)[:, :, None, None] + Sc_c
        return h, h_prev

    (h_final, h_prevs) = jax.lax.scan(
        body, h0, (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(T, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                 # (B,nc,H,P,N)

    # inter-chunk contribution: C_i · h_prev decayed by exp(cum_i)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         Cc, h_prevs, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)
    y = y + D[None, None, :, None] * xh.astype(jnp.float32)
    y = y[:, :S_orig]
    return y.astype(xh.dtype), h_final


def ssd_step(h, x, dt, A, Bv, Cv, D):
    """One recurrent step.  h: (B,H,P,N); x: (B,H,P); dt: (B,H);
    Bv, Cv: (B,N)."""
    a = jnp.exp(dt.astype(jnp.float32) * A)              # (B,H)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(jnp.float32),
                     Bv.astype(jnp.float32), x.astype(jnp.float32))
    h = h * a[:, :, None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", h, Cv.astype(jnp.float32))
    y = y + D[None, :, None] * x.astype(jnp.float32)
    return h, y.astype(x.dtype)


# --------------------------------------------------------------------- #
# mixer forward
# --------------------------------------------------------------------- #

def mixer_forward(pm: Params, x, cfg: ModelConfig):
    """x: (B,S,d) → (B,S,d).  The SSD scan and the gated output norm run
    on ``cfg.kernel_backend`` (xla | pallas | pallas_interpret)."""
    s, di, H, Pd, N = _dims(cfg)
    B_, S, _ = x.shape
    z = x @ pm["w_z"].astype(x.dtype)
    xin = x @ pm["w_x"].astype(x.dtype)
    Bm = x @ pm["w_B"].astype(x.dtype)
    Cm = x @ pm["w_C"].astype(x.dtype)
    dt = jax.nn.softplus((x @ pm["w_dt"].astype(x.dtype))
                         .astype(jnp.float32)
                         + pm["dt_bias"].astype(jnp.float32))
    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xbc = jax.nn.silu(causal_conv1d(xbc, pm["conv_w"], pm["conv_b"]))
    xin, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    xh = xin.reshape(B_, S, H, Pd)
    A = -jnp.exp(pm["A_log"].astype(jnp.float32))
    kb = cfg.kernel_backend
    y, _ = KB.ssd(xh, dt, A, Bm, Cm, pm["D"].astype(jnp.float32),
                  chunk=s.chunk_size, backend=kb)
    y = y.reshape(B_, S, di)
    y = rmsnorm(y * jax.nn.silu(z), pm["norm"], cfg.norm_eps, backend=kb)
    return y @ pm["w_out"].astype(x.dtype)


def mixer_decode(pm: Params, x, state: Params, pos, cfg: ModelConfig):
    """x: (B,1,d); state: {"h": (B,H,P,N), "conv": (B,K-1,di+2N)}."""
    s, di, H, Pd, N = _dims(cfg)
    B_ = x.shape[0]
    xt = x[:, 0]
    z = xt @ pm["w_z"].astype(x.dtype)
    xin = xt @ pm["w_x"].astype(x.dtype)
    Bm = xt @ pm["w_B"].astype(x.dtype)
    Cm = xt @ pm["w_C"].astype(x.dtype)
    dt = jax.nn.softplus((xt @ pm["w_dt"].astype(x.dtype))
                         .astype(jnp.float32)
                         + pm["dt_bias"].astype(jnp.float32))
    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)         # (B, di+2N)
    conv_buf = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)
    w = pm["conv_w"].astype(x.dtype)
    out = jnp.einsum("bkc,kc->bc", conv_buf, w) + pm["conv_b"].astype(x.dtype)
    xbc = jax.nn.silu(out)
    new_conv = conv_buf[:, 1:]
    xin, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    xh = xin.reshape(B_, H, Pd)
    A = -jnp.exp(pm["A_log"].astype(jnp.float32))
    h, y = ssd_step(state["h"], xh, dt, A, Bm, Cm,
                    pm["D"].astype(jnp.float32))
    y = y.reshape(B_, di)
    y = rmsnorm(y * jax.nn.silu(z), pm["norm"], cfg.norm_eps)
    out = (y @ pm["w_out"].astype(x.dtype))[:, None]
    return out, {"h": h, "conv": new_conv}


# --------------------------------------------------------------------- #
# model-level API (mirrors transformer.py)
# --------------------------------------------------------------------- #

def forward_hidden(params: Params, cfg: ModelConfig, tokens, *,
                   prefix_emb=None, dtype=jnp.bfloat16, remat=True,
                   multi_pod=False, seq_shard=True, **_):
    batch_spec = fsdp_axis(multi_pod)
    x = params["embed"]["tok"].astype(dtype)[tokens]
    res_spec = (residual_spec(batch_spec, x.shape[1]) if seq_shard
                else P(batch_spec, None, None))
    x = constrain(x, res_spec)

    def body(x, pl):
        h = rmsnorm(x, pl["norm"], cfg.norm_eps,
                    backend=cfg.kernel_backend)
        y = mixer_forward(pl["mixer"], h, cfg)
        y = constrain(x + y, res_spec)
        return y, {}

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return rmsnorm(x, params["final_norm"], cfg.norm_eps,
                   backend=cfg.kernel_backend), {}


def loss_fn(params, cfg, batch, *, z_loss=0.0, dtype=jnp.bfloat16,
            remat=True, multi_pod=False, **_):
    h, _ = forward_hidden(params, cfg, batch["tokens"], dtype=dtype,
                          remat=remat, multi_pod=multi_pod)
    h = constrain(h, P(fsdp_axis(multi_pod), None, None))
    mask = batch.get("mask", jnp.ones(batch["labels"].shape, jnp.float32))
    loss, z_sq = cross_entropy_chunked(
        h, params["embed"], batch["labels"], mask, cfg.vocab_size,
        z_loss=z_loss,
        logits_spec=P(fsdp_axis(multi_pod), None, "model"))
    return loss, {"ce_loss": loss, "z_sq": z_sq, "loss": loss}


def _cache_struct(cfg: ModelConfig, batch: int, max_len: int = 0,
                  dtype=jnp.bfloat16):
    """SSM 'cache' = recurrent state; max_len is irrelevant (O(1))."""
    return _state_struct(cfg, batch, dtype)


def _state_struct(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    s, di, H, Pd, N = _dims(cfg)
    L = cfg.n_layers
    return {
        "h": jnp.zeros((L, batch, H, Pd, N), jnp.float32),
        "conv": jnp.zeros((L, batch, s.d_conv - 1, di + 2 * N), dtype),
    }


def prefill(params, cfg, tokens, *, cache_len_cap=None, dtype=jnp.bfloat16,
            multi_pod=False, seq_shard=True, **_):
    batch_spec = fsdp_axis(multi_pod)
    s, di, H, Pd, N = _dims(cfg)
    x = params["embed"]["tok"].astype(dtype)[tokens]
    B_, S, _ = x.shape
    res_spec = (residual_spec(batch_spec, S) if seq_shard
                else P(batch_spec, None, None))
    x = constrain(x, res_spec)

    def body(x, pl):
        pm = pl["mixer"]
        h_in = rmsnorm(x, pl["norm"], cfg.norm_eps)
        z = h_in @ pm["w_z"].astype(x.dtype)
        xin = h_in @ pm["w_x"].astype(x.dtype)
        Bm = h_in @ pm["w_B"].astype(x.dtype)
        Cm = h_in @ pm["w_C"].astype(x.dtype)
        dt = jax.nn.softplus((h_in @ pm["w_dt"].astype(x.dtype))
                             .astype(jnp.float32)
                             + pm["dt_bias"].astype(jnp.float32))
        xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)
        conv_tail = xbc[:, -(s.d_conv - 1):]
        xbc = jax.nn.silu(causal_conv1d(xbc, pm["conv_w"], pm["conv_b"]))
        xin, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
        xh = xin.reshape(B_, S, H, Pd)
        A = -jnp.exp(pm["A_log"].astype(jnp.float32))
        y, h_fin = ssd_chunked(xh, dt, A, Bm, Cm,
                               pm["D"].astype(jnp.float32),
                               chunk=s.chunk_size)
        y = y.reshape(B_, S, di)
        y = rmsnorm(y * jax.nn.silu(z), pm["norm"], cfg.norm_eps)
        out = y @ pm["w_out"].astype(x.dtype)
        return constrain(x + out, res_spec), \
            {"h": h_fin, "conv": conv_tail.astype(dtype)}

    x, state = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x[:, -1:])
    return logits, state, jnp.asarray(S, jnp.int32)


def decode_step(params, cfg, cache, cache_len, token, *,
                dtype=jnp.bfloat16, multi_pod=False, **_):
    batch_spec = fsdp_axis(multi_pod)
    x = params["embed"]["tok"].astype(dtype)[token]
    x = constrain(x, P(batch_spec, None, None))

    def body(x, xs):
        pl, st = xs
        h = rmsnorm(x, pl["norm"], cfg.norm_eps)
        y, new_st = mixer_decode(pl["mixer"], h, st, cache_len, cfg)
        return x + y, new_st

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), new_cache, cache_len + 1
