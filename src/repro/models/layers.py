"""Shared building blocks: RMSNorm, RoPE, SwiGLU/GeLU MLPs, initializers,
sharding helpers.  Pure-functional: params are pytrees of jnp arrays."""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import backend as KB

Params = Dict[str, Any]

# --------------------------------------------------------------------- #
# sharding helpers
# --------------------------------------------------------------------- #

def fsdp_axis(multi_pod: bool):
    """The axis (or axes) weights/batches are FSDP/data sharded over."""
    return ("pod", "data") if multi_pod else "data"


def constrain(x, spec: P):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def residual_spec(batch_axes, seq_len: int) -> P:
    """Sharding for the residual stream between blocks.  Sequence
    parallelism (Megatron-SP): shard the seq dim over 'model' so the
    per-layer saved activations (what jax.checkpoint keeps for backward)
    are 16× smaller; XLA inserts the all-gather before attention and the
    reduce-scatter after the output projection automatically."""
    if seq_len % 16 == 0:
        return P(batch_axes, "model", None)
    return P(batch_axes, None, None)


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #

def trunc_normal(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -3.0, 3.0, shape, dtype)


def dense_init(key, d_in: int, d_out: int, *, std: Optional[float] = None,
               stack: Tuple[int, ...] = ()):
    std = std if std is not None else 1.0 / math.sqrt(d_in)
    return trunc_normal(key, (*stack, d_in, d_out), std=std)


# --------------------------------------------------------------------- #
# norms / activations
# --------------------------------------------------------------------- #

def rmsnorm(x, scale, eps: float = 1e-5, backend: str = "xla"):
    """Delegates to the kernel backend registry; the ``xla`` entry is
    ``kernels.ref.rmsnorm_ref`` — the single RMSNorm source of truth."""
    return KB.rmsnorm(x, scale, eps=eps, backend=backend)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# --------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------- #

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    angles = angles[..., None, :]                       # (...,S,1,hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------- #

def init_mlp(key, d_model: int, d_ff: int, act: str, n_layers_scale: int,
             stack: Tuple[int, ...] = ()) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    out_std = 0.02 / math.sqrt(2 * max(n_layers_scale, 1))
    p = {"w_up": dense_init(k2, d_model, d_ff, std=0.02, stack=stack),
         "w_down": dense_init(k3, d_ff, d_model, std=out_std, stack=stack)}
    if act == "silu":  # SwiGLU
        p["w_gate"] = dense_init(k1, d_model, d_ff, std=0.02, stack=stack)
    return p


def mlp(params: Params, x, act: str):
    up = x @ params["w_up"].astype(x.dtype)
    if "w_gate" in params:
        gate = x @ params["w_gate"].astype(x.dtype)
        h = act_fn(act)(gate) * up
    else:
        h = act_fn(act)(up)
    return h @ params["w_down"].astype(x.dtype)


def mlp_specs(act: str, fsdp, lead: Tuple = ()) -> Params:
    base = {"w_up": P(*lead, fsdp, "model"),
            "w_down": P(*lead, "model", fsdp)}
    if act == "silu":
        base["w_gate"] = P(*lead, fsdp, "model")
    return base


# --------------------------------------------------------------------- #
# embeddings / lm head
# --------------------------------------------------------------------- #

def init_embed(key, vocab: int, d_model: int, tie: bool) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"tok": trunc_normal(k1, (vocab, d_model), std=0.02)}
    if not tie:
        p["lm_head"] = trunc_normal(k2, (d_model, vocab), std=0.02)
    return p


def embed_specs(tie: bool, fsdp) -> Params:
    p = {"tok": P("model", fsdp)}
    if not tie:
        p["lm_head"] = P(fsdp, "model")
    return p


def lm_head_matrix(embed_params: Params):
    if "lm_head" in embed_params:
        return embed_params["lm_head"]
    return embed_params["tok"].T


def cross_entropy_chunked(h, embed_params: Params, labels, mask,
                          logical_vocab: int, *, z_loss: float = 0.0,
                          chunk: int = 512, logits_spec: Optional[P] = None):
    """Loss over (B,S,d) hiddens vs (B,S) labels without materializing
    (B,S,V) logits: lax.scan over sequence chunks, each chunk's f32
    logits sharded over 'model' on the vocab dim (a 256k vocab chunk
    would otherwise be 8+ GB/device) and rematted in backward.
    Returns (loss, z_sq) token-means in f32."""
    B, S, d = h.shape
    W = lm_head_matrix(embed_params)
    V = W.shape[-1]
    chunk = min(chunk, S)
    n_chunks = S // chunk
    rem = S - n_chunks * chunk
    vocab_ok = (jnp.arange(V) < logical_vocab)

    def chunk_loss(hc, yc, mc):
        logits = (hc @ W.astype(hc.dtype)).astype(jnp.float32)
        if logits_spec is not None:
            logits = constrain(logits, logits_spec)
        logits = jnp.where(vocab_ok, logits, -1e30)
        z = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        nll = (z - ll) * mc
        return jnp.sum(nll), jnp.sum(jnp.square(z) * mc), jnp.sum(mc)

    def body(carry, xs):
        hc, yc, mc = xs
        l, zs, n = chunk_loss(hc, yc, mc)
        return (carry[0] + l, carry[1] + zs, carry[2] + n), None

    body = jax.checkpoint(body)   # recompute chunk logits in backward

    hs = h[:, :n_chunks * chunk].reshape(B, n_chunks, chunk, d)
    ys = labels[:, :n_chunks * chunk].reshape(B, n_chunks, chunk)
    ms = mask[:, :n_chunks * chunk].reshape(B, n_chunks, chunk)
    xs = (jnp.moveaxis(hs, 1, 0), jnp.moveaxis(ys, 1, 0),
          jnp.moveaxis(ms, 1, 0))
    (tot, z_sq, n_tok), _ = jax.lax.scan(body, (0.0, 0.0, 0.0), xs)
    if rem:
        l, zs, n = chunk_loss(h[:, -rem:], labels[:, -rem:], mask[:, -rem:])
        tot, z_sq, n_tok = tot + l, z_sq + zs, n_tok + n
    n_tok = jnp.maximum(n_tok, 1.0)
    loss = tot / n_tok
    if z_loss:
        loss = loss + z_loss * z_sq / n_tok
    return loss, z_sq / n_tok
