from repro.models import registry
from repro.models.registry import (cache_struct, concrete_inputs,
                                   decode_step, forward_hidden, init_params,
                                   input_shardings, input_specs, loss_fn,
                                   param_specs, prefill)

__all__ = ["registry", "cache_struct", "concrete_inputs", "decode_step",
           "forward_hidden", "init_params", "input_shardings",
           "input_specs", "loss_fn", "param_specs", "prefill"]
