"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427): RG-LRU recurrent
blocks + local sliding-window attention in a 2:1 pattern, each followed
by an MLP.

Layer stacking: the repeating pattern (recurrent, recurrent, attention)
is scanned as a "super-layer" triple; the remainder (38 = 12×3 + 2) is
unrolled.  The RG-LRU linear recurrence uses ``lax.associative_scan``
for training/prefill and an O(1) step for decode.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import HybridConfig, ModelConfig
from repro.configs.base import _pattern as pattern_of
from repro.models import attention as A
from repro.models.layers import (Params, constrain, cross_entropy_chunked,
                                 dense_init, embed_specs, fsdp_axis,
                                 init_embed, init_mlp, mlp, mlp_specs,
                                 residual_spec, rmsnorm, trunc_normal)
from repro.models.mamba2 import causal_conv1d
from repro.models.transformer import logits_from_hidden

LRU_C = 8.0


# --------------------------------------------------------------------- #
# RG-LRU core
# --------------------------------------------------------------------- #

def _combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, b1 * a2 + b2


def rglru_scan(y, r, i, lam, h0=None, chunk: int = 512):
    """y, r, i: (B,S,W); lam: (W,) recurrence parameter.
    h_t = a_t h_{t-1} + sqrt(1-a_t²)(i_t ⊙ y_t),  a_t = exp(-c·softplus(λ)·r_t)

    Chunked: an associative scan *within* each chunk (parallel depth
    log Q) and a sequential carry across chunks — bounds the live
    intermediates to O(B·Q·W·log Q) instead of O(B·S·W·log S), which at
    lru_width 4096 / seq 4k was >13 GB/device of f32 scan temporaries.
    """
    log_a = -LRU_C * jax.nn.softplus(lam.astype(jnp.float32)) \
        * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) \
        * (i.astype(jnp.float32) * y.astype(jnp.float32))

    B, S, W = gated.shape
    Q = min(chunk, S)
    if S % Q:
        pad = Q - S % Q
        # a=1, b=0 padding carries state unchanged and emits garbage we
        # slice off
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        gated = jnp.pad(gated, ((0, 0), (0, pad), (0, 0)))
    nc = (S + Q - 1) // Q
    ac = jnp.moveaxis(a.reshape(B, nc, Q, W), 1, 0)
    bc = jnp.moveaxis(gated.reshape(B, nc, Q, W), 1, 0)

    h_init = (jnp.zeros((B, W), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def body(h_prev, xs):
        a_c, b_c = xs                                  # (B,Q,W)
        a_cum, b_loc = jax.lax.associative_scan(_combine, (a_c, b_c),
                                                axis=1)
        h_c = b_loc + a_cum * h_prev[:, None, :]
        return h_c[:, -1], h_c

    body = jax.checkpoint(body)
    h_last, hs = jax.lax.scan(body, h_init, (ac, bc))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, nc * Q, W)[:, :S]
    return hs.astype(y.dtype), h_last


def rglru_step(h, y, r, i, lam):
    """One step: h, y, r, i: (B,W)."""
    log_a = -LRU_C * jax.nn.softplus(lam.astype(jnp.float32)) \
        * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    h = a * h.astype(jnp.float32) \
        + jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) \
        * (i.astype(jnp.float32) * y.astype(jnp.float32))
    return h.astype(y.dtype), h


# --------------------------------------------------------------------- #
# blocks
# --------------------------------------------------------------------- #

def init_recurrent(key, cfg: ModelConfig, stack=()) -> Params:
    h = cfg.hybrid or HybridConfig()
    w = h.lru_width or cfg.d_model
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    out_std = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "w_gate": dense_init(ks[0], d, w, std=0.02, stack=stack),
        "w_x": dense_init(ks[1], d, w, std=0.02, stack=stack),
        "conv_w": trunc_normal(ks[2], (*stack, h.conv1d_width, w), std=0.2),
        "conv_b": jnp.zeros((*stack, w)),
        "w_r": dense_init(ks[3], w, w, std=0.02, stack=stack),
        "w_i": dense_init(ks[4], w, w, std=0.02, stack=stack),
        "lam": jnp.broadcast_to(
            jnp.log(jnp.expm1(jnp.linspace(0.1, 0.5, w))), (*stack, w)),
        "w_out": dense_init(ks[5], w, d, std=out_std, stack=stack),
    }


def recurrent_specs(fsdp, lead=()) -> Params:
    return {
        "w_gate": P(*lead, fsdp, "model"),
        "w_x": P(*lead, fsdp, "model"),
        "conv_w": P(*lead, None, "model"),
        "conv_b": P(*lead, "model"),
        "w_r": P(*lead, fsdp, "model"),
        "w_i": P(*lead, fsdp, "model"),
        "lam": P(*lead, "model"),
        "w_out": P(*lead, "model", fsdp),
    }


def recurrent_forward(pr: Params, x, cfg: ModelConfig, state=None):
    """x: (B,S,d).  state: None or {"conv": (B,K-1,W), "h": (B,W)} for
    streaming prefill continuation.  Returns (out, new_state)."""
    h_cfg = cfg.hybrid or HybridConfig()
    gate = jax.nn.gelu(x @ pr["w_gate"].astype(x.dtype))
    y = x @ pr["w_x"].astype(x.dtype)
    conv_tail = y[:, -(h_cfg.conv1d_width - 1):]
    y = causal_conv1d(y, pr["conv_w"], pr["conv_b"])
    r = jax.nn.sigmoid(y @ pr["w_r"].astype(x.dtype))
    i = jax.nn.sigmoid(y @ pr["w_i"].astype(x.dtype))
    h0 = state["h"] if state is not None else None
    hs, h_last = rglru_scan(y, r, i, pr["lam"], h0=h0)
    out = (gate * hs) @ pr["w_out"].astype(x.dtype)
    new_state = {"conv": conv_tail, "h": h_last}
    return out, new_state


def recurrent_decode(pr: Params, x, state: Params, cfg: ModelConfig):
    """x: (B,1,d); state: {"conv": (B,K-1,W), "h": (B,W)}."""
    xt = x[:, 0]
    gate = jax.nn.gelu(xt @ pr["w_gate"].astype(x.dtype))
    y = xt @ pr["w_x"].astype(x.dtype)
    buf = jnp.concatenate([state["conv"], y[:, None]], axis=1)
    w = pr["conv_w"].astype(x.dtype)
    y = jnp.einsum("bkc,kc->bc", buf, w) + pr["conv_b"].astype(x.dtype)
    r = jax.nn.sigmoid(y @ pr["w_r"].astype(x.dtype))
    i = jax.nn.sigmoid(y @ pr["w_i"].astype(x.dtype))
    _, h = rglru_step(state["h"], y, r, i, pr["lam"])
    out = ((gate * h.astype(x.dtype)) @ pr["w_out"].astype(x.dtype))[:, None]
    return out, {"conv": buf[:, 1:], "h": h}


def _temporal(kind: str, key, cfg: ModelConfig, stack=()):
    if kind == "recurrent":
        return init_recurrent(key, cfg, stack=stack)
    return A.init_attention(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim, cfg.n_layers, stack=stack)


def init_layer(key, kind: str, cfg: ModelConfig, stack=()) -> Params:
    kt, km = jax.random.split(key)
    return {
        "kind": kind,  # removed before use; informational
        "temporal": _temporal(kind, kt, cfg, stack=stack),
        "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.act, cfg.n_layers,
                        stack=stack),
        "norm1": jnp.zeros((*stack, cfg.d_model)),
        "norm2": jnp.zeros((*stack, cfg.d_model)),
    }


# --------------------------------------------------------------------- #
# hybrid stack: scanned pattern groups + unrolled remainder
# --------------------------------------------------------------------- #

def _groups(cfg: ModelConfig):
    pat = (cfg.hybrid or HybridConfig()).pattern
    L = cfg.n_layers
    n_full = L // len(pat)
    rem = list(pattern_of(cfg, L))[n_full * len(pat):]
    return pat, n_full, rem


def init_params(key, cfg: ModelConfig) -> Params:
    pat, n_full, rem = _groups(cfg)
    keys = jax.random.split(key, 3 + len(rem))
    p: Params = {
        "embed": init_embed(keys[0], cfg.padded_vocab, cfg.d_model,
                            cfg.tie_embeddings),
        "final_norm": jnp.zeros((cfg.d_model,)),
    }
    if n_full:
        gk = jax.random.split(keys[1], len(pat))
        p["groups"] = {
            f"slot{j}": {k: v for k, v in
                         init_layer(gk[j], pat[j], cfg,
                                    stack=(n_full,)).items()
                         if k != "kind"}
            for j in range(len(pat))
        }
    for r, kind in enumerate(rem):
        p[f"rem{r}"] = {k: v for k, v in
                        init_layer(keys[3 + r], kind, cfg).items()
                        if k != "kind"}
    return p


def _layer_specs(kind: str, cfg: ModelConfig, fsdp, lead=()):
    t = (recurrent_specs(fsdp, lead) if kind == "recurrent"
         else A.attention_specs(fsdp, lead))
    return {"temporal": t,
            "mlp": mlp_specs(cfg.act, fsdp, lead),
            "norm1": P(*lead, None), "norm2": P(*lead, None)}


def param_specs(cfg: ModelConfig, multi_pod: bool = False) -> Params:
    f = fsdp_axis(multi_pod)
    pat, n_full, rem = _groups(cfg)
    p: Params = {
        "embed": embed_specs(cfg.tie_embeddings, f),
        "final_norm": P(None),
    }
    if n_full:
        p["groups"] = {f"slot{j}": _layer_specs(pat[j], cfg, f, lead=(None,))
                       for j in range(len(pat))}
    for r, kind in enumerate(rem):
        p[f"rem{r}"] = _layer_specs(kind, cfg, f)
    return p


def _apply_layer(pl: Params, x, kind: str, cfg: ModelConfig, *, res_spec,
                 attn_chunk=1024):
    h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
    if kind == "recurrent":
        t, _ = recurrent_forward(pl["temporal"], h, cfg)
    else:
        w = (cfg.hybrid or HybridConfig()).local_window
        t, _ = A.attn_forward(pl["temporal"], h, n_heads=cfg.n_heads,
                              n_kv_heads=cfg.n_kv_heads,
                              head_dim=cfg.head_dim,
                              rope_theta=cfg.rope_theta, causal=True,
                              window=w, chunk=attn_chunk)
    x = constrain(x + t, res_spec)
    h = rmsnorm(x, pl["norm2"], cfg.norm_eps)
    x = constrain(x + mlp(pl["mlp"], h, cfg.act), res_spec)
    return x


def forward_hidden(params: Params, cfg: ModelConfig, tokens, *,
                   prefix_emb=None, dtype=jnp.bfloat16, remat=True,
                   multi_pod=False, attn_chunk=1024, seq_shard=True, **_):
    batch_spec = fsdp_axis(multi_pod)
    pat, n_full, rem = _groups(cfg)
    x = params["embed"]["tok"].astype(dtype)[tokens]
    res_spec = (residual_spec(batch_spec, x.shape[1]) if seq_shard
                else P(batch_spec, None, None))
    x = constrain(x, res_spec)

    if n_full:
        def body(x, pg):
            for j, kind in enumerate(pat):
                fn = lambda x, pl, kind=kind: _apply_layer(
                    pl, x, kind, cfg, res_spec=res_spec,
                    attn_chunk=attn_chunk)
                if remat:
                    # nested per-layer remat: the group backward then
                    # recomputes one layer at a time, so the live
                    # working set is a single layer's interior, not the
                    # whole (rec, rec, attn) triple's
                    fn = jax.checkpoint(fn)
                x = fn(x, pg[f"slot{j}"])
            return x, None

        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["groups"])
    for r, kind in enumerate(rem):
        fn = lambda x, pl=params[f"rem{r}"], kind=kind: _apply_layer(
            pl, x, kind, cfg, res_spec=res_spec, attn_chunk=attn_chunk)
        x = jax.checkpoint(fn)(x) if remat else fn(x)
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), {}


def loss_fn(params, cfg, batch, *, z_loss=0.0, dtype=jnp.bfloat16,
            remat=True, multi_pod=False, **_):
    h, _ = forward_hidden(params, cfg, batch["tokens"], dtype=dtype,
                          remat=remat, multi_pod=multi_pod)
    h = constrain(h, P(fsdp_axis(multi_pod), None, None))
    mask = batch.get("mask", jnp.ones(batch["labels"].shape, jnp.float32))
    loss, z_sq = cross_entropy_chunked(
        h, params["embed"], batch["labels"], mask, cfg.vocab_size,
        z_loss=z_loss,
        logits_spec=P(fsdp_axis(multi_pod), None, "model"))
    return loss, {"ce_loss": loss, "z_sq": z_sq, "loss": loss}


# --------------------------------------------------------------------- #
# serving: per-layer heterogeneous caches (python-structured, since the
# layer list is static)
# --------------------------------------------------------------------- #

def _iter_layers(params: Params, cfg: ModelConfig):
    """Yield (kind, params_one_layer) in network order (unstacks groups)."""
    pat, n_full, rem = _groups(cfg)
    for g in range(n_full):
        for j, kind in enumerate(pat):
            pl = jax.tree.map(lambda a: a[g], params["groups"][f"slot{j}"])
            yield kind, pl
    for r, kind in enumerate(rem):
        yield kind, params[f"rem{r}"]


def _cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    h = cfg.hybrid or HybridConfig()
    w = h.lru_width or cfg.d_model
    caches = []
    for kind in pattern_of(cfg, cfg.n_layers):
        if kind == "recurrent":
            caches.append({
                "conv": jnp.zeros((batch, h.conv1d_width - 1, w), dtype),
                "h": jnp.zeros((batch, w), jnp.float32),
            })
        else:
            W = min(h.local_window, max_len)
            caches.append(A.init_ring_cache(batch, W, cfg.n_kv_heads,
                                            cfg.head_dim, dtype))
    return caches


def prefill(params, cfg, tokens, *, cache_len_cap: int, dtype=jnp.bfloat16,
            multi_pod=False, attn_chunk=1024, seq_shard=True, **_):
    batch_spec = fsdp_axis(multi_pod)
    h_cfg = cfg.hybrid or HybridConfig()
    x = params["embed"]["tok"].astype(dtype)[tokens]
    B_, S, _ = x.shape
    res_spec = (residual_spec(batch_spec, S) if seq_shard
                else P(batch_spec, None, None))
    x = constrain(x, res_spec)
    caches = []
    for kind, pl in _iter_layers(params, cfg):
        h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
        if kind == "recurrent":
            t, st = recurrent_forward(pl["temporal"], h, cfg)
            caches.append({"conv": st["conv"].astype(dtype), "h": st["h"]})
        else:
            W = min(h_cfg.local_window, cache_len_cap)
            t, (k, v) = A.attn_forward(
                pl["temporal"], h, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta, causal=True,
                window=h_cfg.local_window, chunk=attn_chunk)
            caches.append(A.ring_from_prefill(k, v, S, W, dtype=dtype))
        x = x + t
        hh = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        x = constrain(x + mlp(pl["mlp"], hh, cfg.act), res_spec)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return logits_from_hidden(params, cfg, x[:, -1:]), caches, \
        jnp.asarray(S, jnp.int32)


def decode_step(params, cfg, cache, cache_len, token, *, dtype=jnp.bfloat16,
                multi_pod=False, **_):
    batch_spec = fsdp_axis(multi_pod)
    h_cfg = cfg.hybrid or HybridConfig()
    x = params["embed"]["tok"].astype(dtype)[token]
    x = constrain(x, P(batch_spec, None, None))
    new_caches = []
    for (kind, pl), cl in zip(_iter_layers(params, cfg), cache):
        h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
        if kind == "recurrent":
            t, st = recurrent_decode(pl["temporal"], h, cl, cfg)
        else:
            t, st = A.decode_attn(pl["temporal"], h, cl, cache_len,
                                  n_heads=cfg.n_heads,
                                  n_kv_heads=cfg.n_kv_heads,
                                  head_dim=cfg.head_dim,
                                  rope_theta=cfg.rope_theta,
                                  window=h_cfg.local_window)
        new_caches.append(st)
        x = x + t
        hh = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        x = x + mlp(pl["mlp"], hh, cfg.act)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), new_caches, cache_len + 1
