"""GQA attention: flash-style chunked online-softmax (XLA path), RoPE,
sliding windows, full and ring KV caches.

The chunked `lax.scan` formulation bounds activation memory to
O(S · chunk) instead of O(S²) — this is the TPU-native adaptation of
flash attention used for distributed lowering; the Pallas kernel in
``repro.kernels.flash_attention`` is the single-core hot-spot version.

``block_skip=True`` switches to triangular blocking: each query chunk
only attends to the key chunks its causal/window mask can reach, halving
attention FLOPs at long sequence length (a beyond-paper §Perf lever).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import backend as KB
from repro.models.layers import apply_rope, dense_init

Params = Dict[str, Any]

NEG_INF = -1e30


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, n_layers_scale: int,
                   stack: Tuple[int, ...] = ()) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    out_std = 0.02 / math.sqrt(2 * max(n_layers_scale, 1))
    return {
        "w_q": dense_init(kq, d_model, n_heads * head_dim, std=0.02,
                          stack=stack),
        "w_k": dense_init(kk, d_model, n_kv_heads * head_dim, std=0.02,
                          stack=stack),
        "w_v": dense_init(kv, d_model, n_kv_heads * head_dim, std=0.02,
                          stack=stack),
        "w_o": dense_init(ko, n_heads * head_dim, d_model, std=out_std,
                          stack=stack),
    }


def attention_specs(fsdp, lead: Tuple = ()) -> Params:
    return {"w_q": P(*lead, fsdp, "model"),
            "w_k": P(*lead, fsdp, "model"),
            "w_v": P(*lead, fsdp, "model"),
            "w_o": P(*lead, "model", fsdp)}


# --------------------------------------------------------------------- #
# core chunked attention
# --------------------------------------------------------------------- #

def _mask(qpos, kpos, *, causal: bool, window: Optional[int], kv_len=None):
    """(..., Sq, Sk) boolean mask from absolute positions."""
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    m = k >= 0
    if causal:
        m &= k <= q
    if window is not None:
        m &= k > q - window
    if kv_len is not None:
        m &= k < kv_len
    return m


def _attend(q, k, v, qpos, kpos, *, causal, window, kv_len, scale):
    """One (q-block × kv-block) attention with GQA grouping.

    q: (B, Sq, H, hd); k,v: (B, Sk, Hkv, hd).
    Returns un-normalized (o, m, l) online-softmax stats.
    """
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = _mask(qpos, kpos, causal=causal, window=window, kv_len=kv_len)
    s = jnp.where(mask[:, None, None] if mask.ndim == 3 else mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)                              # (B,Hkv,G,Sq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o, m, l


def chunked_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                      kv_len=None, kpos=None, chunk=1024,
                      block_skip=False):
    """Online-softmax attention, scanning kv chunks.

    q: (B, Sq, H, hd); k,v: (B, Sk, Hkv, hd).
    q_offset: absolute position of q[0] (traced ok).  kpos: optional
    explicit absolute positions of keys (B-independent, (Sk,)) — used by
    ring caches; defaults to arange(Sk).
    """
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    qpos = q_offset + jnp.arange(Sq)
    if kpos is None:
        kpos = jnp.arange(Sk)

    chunk = min(chunk, Sk)
    if Sk % chunk != 0:  # pad keys to a chunk multiple with invalid slots
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.concatenate([kpos, jnp.full((pad,), -1, kpos.dtype)])
        Sk += pad
    n_kv = Sk // chunk

    if block_skip and causal and window is None and Sq == Sk and Sq % chunk == 0:
        return _attention_block_skip(q, k, v, qpos, kpos, chunk, scale,
                                     kv_len)

    ks = jnp.moveaxis(k.reshape(B, n_kv, chunk, Hkv, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, n_kv, chunk, Hkv, hd), 1, 0)
    kps = kpos.reshape(n_kv, chunk)

    G = H // Hkv
    acc0 = jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)

    def body(carry, xs):
        acc, m, l = carry
        kc, vc, kp = xs
        o_c, m_c, l_c = _attend(q, kc, vc, qpos, kp, causal=causal,
                                window=window, kv_len=kv_len, scale=scale)
        m_new = jnp.maximum(m, m_c)
        corr = jnp.exp(m - m_new)
        corr_c = jnp.exp(m_c - m_new)
        acc = acc * corr[..., None] + o_c * corr_c[..., None]
        l = l * corr + l_c * corr_c
        return (acc, m_new, l), None

    # flash-attention-style backward: recompute the (Sq × chunk) score/
    # prob blocks instead of saving one per chunk iteration — the scan's
    # saved residuals were the dominant per-device temp (e.g. 17 GB of
    # f32 p-blocks for recurrentgemma train_4k)
    body = jax.checkpoint(body)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (ks, vs, kps))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,Hkv,G,Sq,hd)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def _attention_block_skip(q, k, v, qpos, kpos, chunk, scale, kv_len):
    """Triangular blocking: query chunk i only visits key chunks 0..i."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    n = Sq // chunk
    outs = []
    for i in range(n):
        qi = q[:, i * chunk:(i + 1) * chunk]
        qp = qpos[i * chunk:(i + 1) * chunk]
        ki = k[:, : (i + 1) * chunk]
        vi = v[:, : (i + 1) * chunk]
        kp = kpos[: (i + 1) * chunk]
        if i == 0:
            o, m, l = _attend(qi, ki, vi, qp, kp, causal=True, window=None,
                              kv_len=kv_len, scale=scale)
            out = o / jnp.maximum(l, 1e-30)[..., None]
        else:
            ks = jnp.moveaxis(ki.reshape(B, i + 1, chunk, Hkv, hd), 1, 0)
            vs = jnp.moveaxis(vi.reshape(B, i + 1, chunk, Hkv, hd), 1, 0)
            kps = kp.reshape(i + 1, chunk)
            acc0 = jnp.zeros((B, Hkv, G, chunk, hd), jnp.float32)
            m0 = jnp.full((B, Hkv, G, chunk), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, Hkv, G, chunk), jnp.float32)

            def body(carry, xs, qi=qi, qp=qp):
                acc, m, l = carry
                kc, vc, kpc = xs
                o_c, m_c, l_c = _attend(qi, kc, vc, qp, kpc, causal=True,
                                        window=None, kv_len=kv_len,
                                        scale=scale)
                m_new = jnp.maximum(m, m_c)
                corr, corr_c = jnp.exp(m - m_new), jnp.exp(m_c - m_new)
                acc = acc * corr[..., None] + o_c * corr_c[..., None]
                return (acc, m_new, l * corr + l_c * corr_c), None

            (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                          (ks, vs, kps))
            out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(jnp.moveaxis(out, 3, 1).reshape(B, chunk, H, hd))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


# --------------------------------------------------------------------- #
# attention block (projections + rope + cache plumbing)
# --------------------------------------------------------------------- #

def attn_forward(params: Params, x, *, n_heads: int, n_kv_heads: int,
                 head_dim: int, rope_theta: float, causal: bool = True,
                 window: Optional[int] = None, positions=None,
                 chunk: int = 1024, block_skip: bool = False,
                 backend: str = "xla"):
    """Training/prefill self-attention over x: (B, S, d).

    ``backend`` selects the kernel backend for the core attention op
    (see repro.kernels.backend); sliding-window attention has no Pallas
    kernel yet, so windowed layers stay on the XLA chunked scan."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = (x @ params["w_q"].astype(x.dtype)).reshape(B, S, n_heads, head_dim)
    k = (x @ params["w_k"].astype(x.dtype)).reshape(B, S, n_kv_heads,
                                                    head_dim)
    v = (x @ params["w_v"].astype(x.dtype)).reshape(B, S, n_kv_heads,
                                                    head_dim)
    if rope_theta:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if backend != "xla" and window is None and causal:
        o = KB.attention(q, k, v, causal=True, backend=backend)
    else:
        o = chunked_attention(q, k, v, causal=causal, window=window,
                              chunk=chunk, block_skip=block_skip)
    o = o.reshape(B, S, n_heads * head_dim)
    out = o @ params["w_o"].astype(x.dtype)
    return out, (k, v)


def init_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
               dtype=jnp.bfloat16) -> Params:
    """Full (non-ring) KV cache."""
    return {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
    }


def init_ring_cache(batch: int, window: int, n_kv_heads: int, head_dim: int,
                    dtype=jnp.bfloat16) -> Params:
    return {
        "k": jnp.zeros((batch, window, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, window, n_kv_heads, head_dim), dtype),
        "pos": jnp.full((window,), -1, jnp.int32),
    }


def ring_from_prefill(k, v, S: int, W: int, dtype=None) -> Params:
    """Build a modular-layout ring cache of capacity W from prefill K/V
    of length S (position p lives at slot p % W, so decode's
    ``slot = pos % W`` overwrites exactly the expired entry)."""
    dtype = dtype or k.dtype
    if S >= W:
        idx = (jnp.arange(W) - S) % W          # slot j ← k_last[idx[j]]
        pos = S - W + idx
        k_ring = k[:, -W:][:, idx]
        v_ring = v[:, -W:][:, idx]
    else:
        pad = W - S
        k_ring = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_ring = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos = jnp.concatenate([jnp.arange(S, dtype=jnp.int32),
                               jnp.full((pad,), -1, jnp.int32)])
    return {"k": k_ring.astype(dtype), "v": v_ring.astype(dtype),
            "pos": pos.astype(jnp.int32)}


def decode_attn(params: Params, x, cache: Params, cache_len, *,
                n_heads: int, n_kv_heads: int, head_dim: int,
                rope_theta: float, window: Optional[int] = None,
                chunk: int = 4096):
    """One-token decode: x (B, 1, d); cache holds ``cache_len`` valid
    entries (full cache) or is a ring buffer with a ``pos`` array.

    ``cache_len`` may be a scalar (all rows at the same depth — the
    training/eval decode path) or a (B,) int32 array of per-request
    depths (the serving path: one fixed-shape executable steps requests
    at ragged positions).  The ragged form supports the full cache only;
    ring caches share one ``pos`` array across the batch, so their
    depths cannot diverge.  Returns (out (B,1,d), new_cache)."""
    B = x.shape[0]
    pos = cache_len                             # scalar or (B,) int32
    ragged = jnp.ndim(pos) == 1
    q = (x @ params["w_q"].astype(x.dtype)).reshape(B, 1, n_heads, head_dim)
    k = (x @ params["w_k"].astype(x.dtype)).reshape(B, 1, n_kv_heads,
                                                    head_dim)
    v = (x @ params["w_v"].astype(x.dtype)).reshape(B, 1, n_kv_heads,
                                                    head_dim)
    if rope_theta:
        ppos = pos[:, None] if ragged else jnp.full((B, 1), pos)
        q = apply_rope(q, ppos, rope_theta)
        k = apply_rope(k, ppos, rope_theta)

    ring = "pos" in cache
    if ragged:
        if ring:
            raise ValueError(
                "per-request cache_len needs a full cache; ring caches "
                "share one position array across the batch")
        # scatter row b's token at its own depth, then mask per request:
        # the same promoted q_offset/kv_len arithmetic as the paged
        # backend, so dense-vs-paged decode is bitwise at equal width
        new_cache = {
            "k": cache["k"].at[jnp.arange(B), pos].set(
                k[:, 0].astype(cache["k"].dtype)),
            "v": cache["v"].at[jnp.arange(B), pos].set(
                v[:, 0].astype(cache["v"].dtype))}
        o = chunked_attention(q, new_cache["k"].astype(q.dtype),
                              new_cache["v"].astype(q.dtype), causal=True,
                              window=window, q_offset=pos[:, None],
                              kv_len=(pos + 1)[:, None, None], chunk=chunk)
        o = o.reshape(B, 1, n_heads * head_dim)
        return o @ params["w_o"].astype(x.dtype), new_cache
    if ring:
        W = cache["k"].shape[1]
        slot = jnp.mod(pos, W)
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        new_pos = cache["pos"].at[slot].set(pos)
        new_cache = {"k": new_k, "v": new_v, "pos": new_pos}
        o = chunked_attention(q, new_k.astype(q.dtype),
                              new_v.astype(q.dtype), causal=True,
                              window=window, q_offset=pos,
                              kpos=new_pos, chunk=min(chunk, W))
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
        new_cache = {"k": new_k, "v": new_v}
        o = chunked_attention(q, new_k.astype(q.dtype),
                              new_v.astype(q.dtype), causal=True,
                              window=window, q_offset=pos,
                              kv_len=pos + 1, chunk=chunk)
    o = o.reshape(B, 1, n_heads * head_dim)
    return o @ params["w_o"].astype(x.dtype), new_cache
