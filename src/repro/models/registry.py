"""Family dispatch + workload input specs.

``input_specs(cfg, shape)`` builds jax.ShapeDtypeStruct stand-ins (no
allocation) for every model input of a workload — the dry-run lowers
against these; ``concrete_inputs`` builds small real arrays for smoke
tests.  ``input_shardings`` gives the matching PartitionSpec tree.

Sharding choices (see DESIGN.md §4): batch over ('pod','data') when it
divides, KV caches shard head_dim over 'model' (kv-head counts are ≤ 8;
head_dim is always a multiple of 16) so the in-place sequence update
stays local.
"""
from __future__ import annotations

import importlib
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.kernels import backend as kernel_backend
from repro.models.layers import fsdp_axis

Params = Dict[str, Any]

_FAMILY_MODULE = {
    "dense": "repro.models.transformer",
    "moe": "repro.models.transformer",
    "vlm": "repro.models.transformer",
    "ssm": "repro.models.mamba2",
    "hybrid": "repro.models.rglru",
    "encdec": "repro.models.encdec",
    "audio": "repro.models.encdec",
}


def family(cfg: ModelConfig):
    # fail fast on a bad backend name here, at dispatch time, instead of
    # deep inside a jitted forward trace
    kernel_backend.resolve(cfg.kernel_backend)
    return importlib.import_module(_FAMILY_MODULE[cfg.arch_type])


def init_params(key, cfg: ModelConfig) -> Params:
    return family(cfg).init_params(key, cfg)


def param_specs(cfg: ModelConfig, multi_pod: bool = False,
                serve_resident: bool = False) -> Params:
    """serve_resident=True drops the FSDP ('data'/'pod') axis from every
    weight spec — weights replicate over the data axis and stay sharded
    over 'model' only, removing the per-step weight all-gather during
    decode (a §Perf lever; costs N·2/16 bytes per device)."""
    specs = family(cfg).param_specs(cfg, multi_pod)
    if not serve_resident:
        return specs

    def strip(spec):
        if not isinstance(spec, P):
            return spec
        cleaned = []
        for ax in spec:
            if ax in ("data", "pod"):
                cleaned.append(None)
            elif isinstance(ax, tuple):
                kept = tuple(a for a in ax if a not in ("data", "pod"))
                cleaned.append(kept[0] if len(kept) == 1 else
                               (kept or None))
            else:
                cleaned.append(ax)
        return P(*cleaned)

    return jax.tree.map(strip, specs,
                        is_leaf=lambda x: isinstance(x, P))


def loss_fn(params, cfg: ModelConfig, batch, **kw):
    return family(cfg).loss_fn(params, cfg, batch, **kw)


def forward_hidden(params, cfg: ModelConfig, tokens, **kw):
    return family(cfg).forward_hidden(params, cfg, tokens, **kw)


def _sc():
    # function-level import: repro.serving.__init__ pulls in the engine,
    # which imports this module — a top-level import would cycle
    from repro.serving import cache as sc
    return sc


def supports_paged(cfg: ModelConfig) -> bool:
    """True when the family can serve from the paged KV pool: the
    transformer families with full attention.  Sliding-window archs keep
    a ring cache whose shared ``pos`` array cannot diverge per request,
    and the recurrent families hold state, not KV."""
    return (_FAMILY_MODULE[cfg.arch_type] == "repro.models.transformer"
            and cfg.sliding_window is None)


def serving_mode(cfg: ModelConfig):
    """How the continuous-batching engine can hold this family's cache:
    ``"paged"`` (token-granular page tables), ``"state"`` (fixed-size
    recurrent state, one page per request), or ``None`` (dense
    ``Server`` only: ring-cache windows share one position array and
    enc-dec needs per-request source embeddings)."""
    if supports_paged(cfg):
        return "paged"
    if cfg.arch_type == "ssm":
        return "state"
    return None


def prefill(params, cfg: ModelConfig, tokens, **kw):
    """Run the prompt and build the decode cache.  Returns
    (logits (B, 1, V), ``serving.DenseKVCache``) — the cache carries its
    own (B,) ``lengths``, so callers no longer thread a scalar
    ``cache_len`` alongside the cache pytree."""
    logits, data, ln = family(cfg).prefill(params, cfg, tokens, **kw)
    B = tokens.shape[0]
    lengths = jnp.full((B,), ln, jnp.int32)
    return logits, _sc().DenseKVCache(data=data, lengths=lengths)


def prefill_ragged(params, cfg: ModelConfig, tokens, lengths, **kw):
    """Bucketed prefill (full-attention transformer families only):
    tokens right-padded to a shared bucket length, ``lengths`` (B,) the
    true prompt lengths.  Returns (logits at each request's last real
    token, raw per-layer k, v (L, B, S, Hkv, hd)) for the cache layer
    (dense assembly or page-pool scatter) to place."""
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"ragged prefill needs full attention; {cfg.arch_type} with "
            f"window={cfg.sliding_window} keeps the exact-length path")
    return family(cfg).prefill_ragged(params, cfg, tokens, lengths, **kw)


def decode_step(params, cfg: ModelConfig, cache, token, **kw):
    """One decode step against a typed KV cache.  ``cache`` is either a
    ``serving.DenseKVCache`` (contiguous per-family cache pytree) or a
    ``serving.PagedKVCache`` (page pool + per-request tables); dispatch
    is on the cache type, so one call site serves both layouts.
    Returns (logits (B, 1, V), new cache of the same type).

    Dense full-attention caches step at per-request depths (the ragged
    ``lengths`` array is passed straight to the family); uniform-layout
    caches (ring windows, recurrent state, enc-dec) require all rows at
    one depth and use ``lengths[0]``."""
    sc = _sc()
    if isinstance(cache, sc.PagedKVCache):
        return sc.paged_decode(params, cfg, cache, token, **kw)
    if not isinstance(cache, sc.DenseKVCache):
        raise TypeError(
            f"decode_step expects a DenseKVCache or PagedKVCache, got "
            f"{type(cache).__name__}; build one with registry.prefill "
            f"or serving.cache helpers")
    cl = cache.lengths if supports_paged(cfg) else cache.lengths[0]
    logits, data, _ = family(cfg).decode_step(params, cfg, cache.data,
                                              cl, token, **kw)
    return logits, sc.DenseKVCache(data=data,
                                   lengths=cache.lengths + 1)


def cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    return family(cfg)._cache_struct(cfg, batch, max_len, dtype)


# --------------------------------------------------------------------- #
# workload inputs
# --------------------------------------------------------------------- #

def _has_frontend(cfg: ModelConfig) -> bool:
    return cfg.arch_type in ("vlm", "audio", "encdec")


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text tokens after the frontend stub's share of the sequence."""
    if _has_frontend(cfg):
        return max(seq_len - cfg.frontend_tokens, 1)
    return seq_len


def train_batch_struct(cfg: ModelConfig, batch: int, seq_len: int):
    st = text_len(cfg, seq_len)
    out = {
        "tokens": jax.ShapeDtypeStruct((batch, st), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, st), jnp.int32),
    }
    if cfg.arch_type == "vlm":
        out["prefix_emb"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    elif cfg.arch_type in ("audio", "encdec"):
        out["src_emb"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    return out


def prefill_struct(cfg: ModelConfig, batch: int, seq_len: int):
    st = text_len(cfg, seq_len)
    out = {"tokens": jax.ShapeDtypeStruct((batch, st), jnp.int32)}
    if cfg.arch_type == "vlm":
        out["prefix_emb"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    elif cfg.arch_type in ("audio", "encdec"):
        out["prefix_emb"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    return out


def decode_struct(cfg: ModelConfig, batch: int, seq_len: int,
                  dtype=jnp.bfloat16):
    # eval_shape: a 512-chip decode cache is hundreds of GB — it must
    # never be allocated on the dry-run host
    data = jax.eval_shape(
        lambda: cache_struct(cfg, batch, seq_len, dtype))
    cache = _sc().DenseKVCache(
        data=data, lengths=jax.ShapeDtypeStruct((batch,), jnp.int32))
    return {
        "cache": cache,
        "token": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: InputShape):
    if shape.mode == "train":
        return train_batch_struct(cfg, shape.global_batch, shape.seq_len)
    if shape.mode == "prefill":
        return prefill_struct(cfg, shape.global_batch, shape.seq_len)
    return decode_struct(cfg, shape.global_batch, shape.seq_len)


# --------------------------------------------------------------------- #
# input shardings
# --------------------------------------------------------------------- #

def _batch_axes(batch: int, multi_pod: bool):
    need = 32 if multi_pod else 16
    if batch % need == 0:
        return fsdp_axis(multi_pod)
    if batch % 16 == 0:
        return "data"
    return None


def _cache_spec(leaf_shape: Tuple[int, ...], b_axes, leading_layer: bool,
                seq_shard: bool = False):
    """Shard batch dim; shard the last dim over 'model' when it is a
    multiple of 16 (head_dim / feature shards).  seq_shard=True shards
    the KV sequence dim over 'model' instead (flash-decode layout: the
    per-shard partial softmax needs only an all-reduce of (B,H,1)
    stats, no KV gather)."""
    spec = [None] * len(leaf_shape)
    bdim = 1 if leading_layer else 0
    if len(leaf_shape) > bdim:
        spec[bdim] = b_axes
    sdim = bdim + 1
    if (seq_shard and len(leaf_shape) >= sdim + 2
            and leaf_shape[sdim] % 16 == 0):
        spec[sdim] = "model"
    elif leaf_shape[-1] % 16 == 0 and len(leaf_shape) >= 2:
        spec[-1] = "model"
    return P(*spec)


def input_shardings(cfg: ModelConfig, shape: InputShape,
                    multi_pod: bool = False,
                    cache_seq_shard: bool = False):
    b = _batch_axes(shape.global_batch, multi_pod)
    if shape.mode in ("train", "prefill"):
        struct = (train_batch_struct if shape.mode == "train"
                  else prefill_struct)(cfg, shape.global_batch,
                                       shape.seq_len)
        out = {}
        for k, v in struct.items():
            out[k] = P(b, None, None) if v.ndim == 3 else P(b, None)
        return out
    # decode: cache leaves are layer-stacked for scanned families,
    # python lists for the hybrid
    struct = decode_struct(cfg, shape.global_batch, shape.seq_len)
    layer_stacked = cfg.arch_type not in ("hybrid",)

    def spec_of(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[-1] == 1 and leaf.ndim == 2:   # token (B,1)
            return P(b, None)
        lead = layer_stacked and leaf.ndim >= 3
        # pos arrays: small ints, replicate
        if leaf.dtype == jnp.int32:
            return P(*([None] * leaf.ndim))
        return _cache_spec(leaf.shape, b, lead,
                           seq_shard=cache_seq_shard)

    cache_spec = jax.tree.map(spec_of, struct["cache"])
    return {"cache": cache_spec, "token": P(b, None)}


# --------------------------------------------------------------------- #
# concrete small inputs for smoke tests
# --------------------------------------------------------------------- #

def concrete_inputs(cfg: ModelConfig, mode: str, batch: int, seq_len: int,
                    seed: int = 0):
    rng = np.random.default_rng(seed)
    st = text_len(cfg, seq_len)
    toks = rng.integers(0, cfg.vocab_size, (batch, st)).astype(np.int32)
    if mode == "train":
        out = {"tokens": jnp.asarray(toks),
               "labels": jnp.asarray(np.roll(toks, -1, axis=1))}
        if cfg.arch_type == "vlm":
            out["prefix_emb"] = jnp.asarray(
                rng.normal(0, 1, (batch, cfg.frontend_tokens,
                                  cfg.frontend_dim)), jnp.bfloat16)
        elif cfg.arch_type in ("audio", "encdec"):
            out["src_emb"] = jnp.asarray(
                rng.normal(0, 1, (batch, cfg.frontend_tokens,
                                  cfg.frontend_dim)), jnp.bfloat16)
        return out
    if mode == "prefill":
        out = {"tokens": jnp.asarray(toks)}
        if _has_frontend(cfg):
            out["prefix_emb"] = jnp.asarray(
                rng.normal(0, 1, (batch, cfg.frontend_tokens,
                                  cfg.frontend_dim)), jnp.bfloat16)
        return out
    data = cache_struct(cfg, batch, seq_len)
    cache = _sc().DenseKVCache(
        data=data, lengths=jnp.full((batch,), seq_len // 2, jnp.int32))
    return {"cache": cache, "token": jnp.asarray(toks[:, :1])}
