"""Adaptive Seesaw (beyond-paper): measurement-triggered cuts.

The paper derives Seesaw's cut points from where a *reference cosine*
would decay by α.  This variant instead watches the quantity the theory
actually cares about — the variance-dominated gradient norm
E‖g‖² ≈ σ²Tr(H)/B (Assumption 2) — and fires a (√α LR cut, ×α batch
ramp) whenever the smoothed loss plateaus, i.e. when the current phase
has extracted its bias reduction and the iterate noise floor dominates
(the regime where Assumption 1 holds and the equivalence applies).

This removes the need to know the total token budget in advance — the
schedule becomes budget-free, which matters for continued-pretraining
runs.  Validated on the exact recursions in tests/test_cbs_adaptive.py
(the adaptive trigger lands its cuts near the cosine-derived points and
matches the final risk of the prescheduled Seesaw within a constant
factor — Corollary 1 applies phase-by-phase regardless of *when* the
cuts fire, as long as α√β is maintained) and on the fused engine in
tests/test_adaptive_engine.py (``--schedule adaptive-seesaw``, see
docs/adaptive.md).

Two observation modes share one plateau test:

- ``observe(loss)`` — host-side exact recursions feed every raw loss;
  the window mean is computed here.
- ``observe_smoothed(ema, n_steps)`` — the production engine path: the
  fused K-step executable accumulates a loss EMA *on device* inside its
  ``lax.scan`` carry and surfaces one scalar per chunk.  The controller
  advances its step count by the chunk's real steps and runs the
  plateau test whenever a window boundary has been crossed, comparing
  the EMA now against the EMA one window ago.  Decisions therefore
  land on chunk boundaries — exactly where the trainer can re-chunk
  the loader and extend the plan.

A cut requires *fresh* plateau evidence: the controller arms on a
window that improved by at least ``rel_threshold`` (descending) and
fires on the first subsequent window that does not.  Firing disarms and
clears the window state, so a forever-flat stream produces exactly one
cut per plateau — not one per window (the pre-fix behaviour: the
stale ``_prev_window_mean`` kept re-triggering every ``window`` steps).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class AdaptiveSeesaw:
    """Plateau-triggered Seesaw controller.

    Feed ``observe(loss)`` once per step (or ``observe_smoothed`` once
    per fused chunk); read ``lr_scale`` / ``batch_multiplier``.  A cut
    fires when a window's loss improvement drops below
    ``rel_threshold`` of the loss scale *after* at least one window
    showed real improvement (the armed state) — each cut needs fresh
    descend-then-plateau evidence.
    """
    alpha: float = 2.0                 # reference decay per cut
    window: int = 50                   # steps per plateau test
    rel_threshold: float = 2e-3        # relative improvement floor
    max_cuts: int = 12
    min_steps_between: int = 50
    # state -------------------------------------------------------------
    n_cuts: int = 0
    steps: int = 0
    last_cut_step: int = 0
    _window_losses: List[float] = field(default_factory=list)
    _prev_window_mean: Optional[float] = None
    _window_start: int = 0             # step the current window opened
    _armed: bool = True                # saw improvement since last cut
    cut_steps: List[int] = field(default_factory=list)

    @property
    def lr_scale(self) -> float:
        return math.sqrt(self.alpha) ** (-self.n_cuts)

    @property
    def batch_multiplier(self) -> float:
        return self.alpha ** self.n_cuts

    # -- the one plateau test ------------------------------------------- #
    def _test_window(self, mean: float) -> bool:
        """Compare this window's smoothed loss against the previous
        window's; fire if armed and the improvement stalled.  Firing
        resets ``_prev_window_mean`` (fresh evidence required) and
        disarms until a window improves again."""
        fired = False
        if self._prev_window_mean is not None:
            improvement = self._prev_window_mean - mean
            scale = max(abs(self._prev_window_mean), 1e-12)
            improving = improvement >= self.rel_threshold * scale
            if improving:
                self._armed = True
            elif (self._armed
                    and self.n_cuts < self.max_cuts
                    and self.steps - self.last_cut_step
                    >= self.min_steps_between):
                self.n_cuts += 1
                self.last_cut_step = self.steps
                self.cut_steps.append(self.steps)
                self._armed = False
                fired = True
        self._window_start = self.steps
        # a fired cut changes the (lr, batch) operating point: the next
        # comparison must be between two post-cut windows, not against
        # the pre-cut plateau (the chain-fire bug)
        self._prev_window_mean = None if fired else mean
        return fired

    # -- per-step host path --------------------------------------------- #
    def observe(self, loss: float) -> bool:
        """Returns True if a cut fires at this step."""
        self.steps += 1
        self._window_losses.append(float(loss))
        if len(self._window_losses) < self.window:
            return False
        mean = sum(self._window_losses) / len(self._window_losses)
        self._window_losses.clear()
        return self._test_window(mean)

    # -- per-chunk engine path ------------------------------------------ #
    def observe_smoothed(self, ema: float, n_steps: int) -> bool:
        """Chunk-boundary observation: the device-accumulated loss EMA
        after advancing ``n_steps`` real steps.  Runs the plateau test
        once per crossed window boundary (a chunk larger than a window
        still tests once — the EMA already summarizes the span).
        Returns True if a cut fires at this boundary."""
        self.steps += int(n_steps)
        if self.steps - self._window_start < self.window:
            return False
        return self._test_window(float(ema))

    # -- checkpointing --------------------------------------------------- #
    def state_dict(self) -> Dict:
        """JSON-able controller state for the checkpoint manifest —
        everything needed to replay the adaptive run bitwise from a
        resume (window phase included, so a checkpoint taken between
        two cuts re-fires the later cuts at identical steps)."""
        return {"n_cuts": self.n_cuts, "steps": self.steps,
                "last_cut_step": self.last_cut_step,
                "window_losses": list(self._window_losses),
                "prev_window_mean": self._prev_window_mean,
                "window_start": self._window_start,
                "armed": self._armed,
                "cut_steps": list(self.cut_steps)}

    def load_state_dict(self, state: Dict) -> "AdaptiveSeesaw":
        self.n_cuts = int(state["n_cuts"])
        self.steps = int(state["steps"])
        self.last_cut_step = int(state["last_cut_step"])
        self._window_losses = [float(x)
                               for x in state.get("window_losses", [])]
        pw = state.get("prev_window_mean")
        self._prev_window_mean = None if pw is None else float(pw)
        self._window_start = int(state.get("window_start", 0))
        self._armed = bool(state.get("armed", True))
        self.cut_steps = [int(s) for s in state["cut_steps"]]
        return self
