"""Adaptive Seesaw (beyond-paper): measurement-triggered cuts.

The paper derives Seesaw's cut points from where a *reference cosine*
would decay by α.  This variant instead watches the quantity the theory
actually cares about — the variance-dominated gradient norm
E‖g‖² ≈ σ²Tr(H)/B (Assumption 2) — and fires a (√α LR cut, ×α batch
ramp) whenever the smoothed loss plateaus, i.e. when the current phase
has extracted its bias reduction and the iterate noise floor dominates
(the regime where Assumption 1 holds and the equivalence applies).

This removes the need to know the total token budget in advance — the
schedule becomes budget-free, which matters for continued-pretraining
runs.  Validated on the exact recursions in tests/test_adaptive.py: the
adaptive trigger lands its cuts near the cosine-derived points and
matches the final risk of the prescheduled Seesaw within a constant
factor (Corollary 1 applies phase-by-phase regardless of *when* the
cuts fire, as long as α√β is maintained).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class AdaptiveSeesaw:
    """Plateau-triggered Seesaw controller.

    Feed ``observe(loss)`` once per step; read ``lr_scale`` /
    ``batch_multiplier``.  A cut fires when the EMA'd loss improvement
    per window drops below ``rel_threshold`` of the loss scale.
    """
    alpha: float = 2.0                 # reference decay per cut
    window: int = 50                   # steps per plateau test
    rel_threshold: float = 2e-3        # relative improvement floor
    max_cuts: int = 12
    min_steps_between: int = 50
    # state -------------------------------------------------------------
    n_cuts: int = 0
    steps: int = 0
    last_cut_step: int = 0
    _window_losses: List[float] = field(default_factory=list)
    _prev_window_mean: Optional[float] = None
    cut_steps: List[int] = field(default_factory=list)

    @property
    def lr_scale(self) -> float:
        return math.sqrt(self.alpha) ** (-self.n_cuts)

    @property
    def batch_multiplier(self) -> float:
        return self.alpha ** self.n_cuts

    def observe(self, loss: float) -> bool:
        """Returns True if a cut fires at this step."""
        self.steps += 1
        self._window_losses.append(float(loss))
        if len(self._window_losses) < self.window:
            return False
        mean = sum(self._window_losses) / len(self._window_losses)
        self._window_losses.clear()
        fired = False
        if (self._prev_window_mean is not None
                and self.n_cuts < self.max_cuts
                and self.steps - self.last_cut_step
                >= self.min_steps_between):
            improvement = self._prev_window_mean - mean
            scale = max(abs(self._prev_window_mean), 1e-12)
            if improvement < self.rel_threshold * scale:
                self.n_cuts += 1
                self.last_cut_step = self.steps
                self.cut_steps.append(self.steps)
                fired = True
        self._prev_window_mean = mean
        return fired
