"""Critical-batch-size estimation from the gradient noise scale
(McCandlish et al., 2018 — the quantity the paper uses to set B₀ = B*).

    B_noise = tr(Σ) / ‖G‖²

estimated from two batch sizes (the unbiased two-point estimator):
given gradient estimates g_small (batch b) and g_big (batch B ≥ 2b),

    ‖G‖²_est  = (B·‖g_big‖² − b·‖g_small‖²) / (B − b)
    tr(Σ)_est = (‖g_small‖² − ‖g_big‖²) / (1/b − 1/B)

Also exposes the *exact* noise scale on the paper's noisy-linear-
regression model (Appendix B gives E‖g‖² in closed form), used to test
the estimator and to reproduce the observation that the noise scale —
and hence the CBS — GROWS during training (McCandlish; paper §2),
which is exactly why a batch ramp is the right shape of schedule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import numpy as np

from repro.core import theory as T


def _sq_norm(tree) -> float:
    return float(sum(np.vdot(np.asarray(x), np.asarray(x)).real
                     for x in jax.tree.leaves(tree)))


def noise_scale_two_point(g_small, g_big, b: int, B: int
                          ) -> Tuple[float, float, float]:
    """Returns (B_noise, |G|² estimate, tr(Σ) estimate)."""
    assert B > b
    ns2 = _sq_norm(g_small)
    nB2 = _sq_norm(g_big)
    g2 = (B * nB2 - b * ns2) / (B - b)
    tr = (ns2 - nB2) / (1.0 / b - 1.0 / B)
    g2 = max(g2, 1e-30)
    return tr / g2, g2, tr


@dataclass
class NoiseScaleMonitor:
    """Online CBS monitor for the trainer: feed per-step (g_micro,
    g_full) pairs from gradient accumulation (micro batch b, full batch
    B) and read an EMA'd noise scale — the point where B ≈ B_noise is
    the CBS and the natural place for the first Seesaw cut."""
    micro_batch: int
    full_batch: int
    ema: float = 0.9
    value: Optional[float] = None

    def update(self, g_micro, g_full) -> float:
        bn, _, _ = noise_scale_two_point(g_micro, g_full,
                                         self.micro_batch,
                                         self.full_batch)
        bn = max(bn, 0.0)
        self.value = bn if self.value is None else \
            self.ema * self.value + (1 - self.ema) * bn
        return self.value


# --------------------------------------------------------------------- #
# exact noise scale on the linear-regression model
# --------------------------------------------------------------------- #

def exact_noise_scale(lam: np.ndarray, sigma2: float, m: np.ndarray,
                      e: Optional[np.ndarray] = None) -> float:
    """tr(Σ)/‖G‖² on x~N(0,H), y=⟨w*,x⟩+ε.  Per Appendix B:
    per-sample gradient second moment (B=1 variance term)
        tr(Σ) = σ²TrH + 2⟨λ², m⟩ + TrH·⟨λ, m⟩ − ⟨λ², e²⟩·0 …
    and the mean-gradient norm ‖G‖² = ⟨λ², e²⟩ for iterate mean e (bias)
    — for the post-burn-in regime (e→0) we use ‖G‖² = ⟨λ², m⟩ (typical
    per-coordinate signal) as the deterministic-gradient proxy."""
    e = np.zeros_like(lam) if e is None else e
    trH = float(np.sum(lam))
    tr_sigma = sigma2 * trH + 2 * float(np.dot(lam * lam, m)) \
        + trH * float(np.dot(lam, m))
    g2 = max(float(np.dot(lam * lam, e * e)),
             float(np.dot(lam * lam, m)), 1e-30)
    return tr_sigma / g2


def noise_scale_trajectory(lam: np.ndarray, sigma2: float, eta: float,
                           batch: int, steps: int, every: int = 10
                           ) -> np.ndarray:
    """Run constant-(η,B) SGD on the exact recursions and record the
    noise scale every ``every`` steps — reproduces 'the noise scale
    increases during training' (paper §2 / McCandlish)."""
    d = lam.shape[0]
    m = np.full(d, 1.0 / d)
    e = np.sqrt(m)
    out = []
    for t in range(steps):
        m, e = T._step(m, e, lam, eta, batch, sigma2)
        if t % every == 0:
            out.append(exact_noise_scale(lam, sigma2, m, e))
    return np.asarray(out)
