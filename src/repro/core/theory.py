"""Exact bias/variance recursions for SGD / normalized SGD on noisy
linear regression — the paper's theoretical engine (Section 5, Appendices
A & B), implemented verbatim in the eigenbasis of H.

State per step (d-vectors, diagonal of the rotated iterate covariance):
    m_{t+1} = (1-ηλ)² ⊙ m_t + (η²/B)(λ² ⊙ m_t + λ ⟨λ, m_t⟩) + (η²σ²/B) λ
    e_{t+1} = (1-ηλ) ⊙ e_t                       (mean of δ_t = w_t − w*)
Excess risk  = ½⟨λ, m⟩.

Normalized SGD (Appendix B): η_eff = η / √(E‖g‖²) with the exact
denominator
    E‖g‖² = (σ²TrH + 2⟨λ², m⟩ + TrH·⟨λ, m⟩)/B + (1−1/B)⟨λ², e²⟩
or the Assumption-2 approximation  E‖g‖² = σ²TrH/B.

These recursions are *exact* expectations — no sampling noise — so the
Theorem 1 / Corollary 1 equivalences can be verified to numerical
precision, and Lemma 4 divergence reproduced, in milliseconds.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class TheoryPhase:
    eta: float          # learning rate during the phase
    batch: float        # batch size during the phase
    steps: int          # number of SGD steps in the phase

    @property
    def samples(self) -> float:
        return self.batch * self.steps


def power_law_spectrum(d: int = 100, a: float = 1.0,
                       trace: float = 1.0) -> np.ndarray:
    lam = np.arange(1, d + 1, dtype=np.float64) ** (-a)
    return lam * (trace / lam.sum())


def stability_eta(lams: np.ndarray) -> float:
    """Theorem 1's step-size condition η ≤ 0.01/Tr(H)."""
    return 0.01 / float(np.sum(lams))


# --------------------------------------------------------------------- #
# core recursion
# --------------------------------------------------------------------- #

def _step(m, e, lam, eta, B, sigma2):
    contract = (1.0 - eta * lam) ** 2
    quad = (eta * eta / B) * (lam * lam * m + lam * np.dot(lam, m))
    m = contract * m + quad + (eta * eta * sigma2 / B) * lam
    e = (1.0 - eta * lam) * e
    return m, e


def effective_grad_norm_sq(m, e, lam, B, sigma2):
    trH = float(np.sum(lam))
    var = (sigma2 * trH + 2.0 * np.dot(lam * lam, m)
           + trH * np.dot(lam, m)) / B
    mean = (1.0 - 1.0 / B) * np.dot(lam * lam, e * e)
    return var + mean


def run_schedule(lam: np.ndarray, sigma2: float,
                 phases: Sequence[TheoryPhase], *,
                 m0: Optional[np.ndarray] = None,
                 e0: Optional[np.ndarray] = None,
                 normalized: bool = False,
                 assume_variance_dominated: bool = False,
                 record_every: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Run the exact recursion.  Returns (risk_at_phase_ends,
    trajectory (tokens, risk) if record_every else empty, final m)."""
    d = lam.shape[0]
    m = np.full(d, 1.0 / d) if m0 is None else m0.astype(np.float64).copy()
    e = np.sqrt(m) if e0 is None else e0.astype(np.float64).copy()
    trH = float(np.sum(lam))
    risks = []
    traj = []
    samples_seen = 0.0
    for ph in phases:
        for t in range(ph.steps):
            eta = ph.eta
            if normalized:
                if assume_variance_dominated:
                    denom = math.sqrt(sigma2 * trH / ph.batch)
                else:
                    denom = math.sqrt(max(effective_grad_norm_sq(
                        m, e, lam, ph.batch, sigma2), 1e-300))
                eta = ph.eta / denom
            m, e = _step(m, e, lam, eta, ph.batch, sigma2)
            samples_seen += ph.batch
            if record_every and (t % record_every == 0):
                traj.append((samples_seen, 0.5 * float(np.dot(lam, m))))
            if not np.isfinite(m).all() or m.max() > 1e12:
                # diverged — record inf and stop
                risks.append(np.inf)
                return (np.asarray(risks),
                        np.asarray(traj) if traj else np.zeros((0, 2)), m)
        risks.append(0.5 * float(np.dot(lam, m)))
    return (np.asarray(risks),
            np.asarray(traj) if traj else np.zeros((0, 2)), m)


def excess_risk(lam, m) -> float:
    return 0.5 * float(np.dot(lam, m))


# --------------------------------------------------------------------- #
# schedule constructors for the theorem setups
# --------------------------------------------------------------------- #

def phase_schedule(eta0: float, b0: float, alpha: float, beta: float,
                   samples_per_phase: Sequence[float]) -> List[TheoryPhase]:
    """(η_k, B_k) = (η α^{-k}, B β^k), phase k processes
    samples_per_phase[k] samples (Theorem 1 setup)."""
    out = []
    for k, P_k in enumerate(samples_per_phase):
        B_k = b0 * beta ** k
        steps = max(int(round(P_k / B_k)), 1)
        out.append(TheoryPhase(eta=eta0 * alpha ** (-k), batch=B_k,
                               steps=steps))
    return out


def warm_start(lam: np.ndarray, sigma2: float, eta0: float, b0: float,
               steps: int, normalized: bool = False) -> np.ndarray:
    """Run a constant-(η,B) burn-in so Assumption 1 (risk ≲ σ²) holds at
    the first cut, mirroring 'well tuned scheduler starts cutting when
    bias is resolved'."""
    _, _, m = run_schedule(lam, sigma2,
                           [TheoryPhase(eta0, b0, steps)],
                           normalized=normalized,
                           assume_variance_dominated=False)
    return m


def theorem1_risk_ratio(lam, sigma2, *, eta0, b0, alpha1, beta1, alpha2,
                        beta2, samples_per_phase, m_start=None) -> float:
    """Risk ratio of the two Theorem-1 processes at the final phase end.
    With α₁β₁ = α₂β₂ the ratio must stay O(1) in phases."""
    ph1 = phase_schedule(eta0, b0, alpha1, beta1, samples_per_phase)
    ph2 = phase_schedule(eta0, b0, alpha2, beta2, samples_per_phase)
    r1, _, _ = run_schedule(lam, sigma2, ph1, m0=m_start)
    r2, _, _ = run_schedule(lam, sigma2, ph2, m0=m_start)
    return float(r1[-1] / r2[-1])


def corollary1_risk_ratio(lam, sigma2, *, eta0, b0, alpha1, beta1, alpha2,
                          beta2, samples_per_phase, m_start=None,
                          variance_dominated=True) -> float:
    """Same for normalized SGD; equivalence requires α√β matched."""
    ph1 = phase_schedule(eta0, b0, alpha1, beta1, samples_per_phase)
    ph2 = phase_schedule(eta0, b0, alpha2, beta2, samples_per_phase)
    kw = dict(normalized=True,
              assume_variance_dominated=variance_dominated)
    r1, _, _ = run_schedule(lam, sigma2, ph1, m0=m_start, **kw)
    r2, _, _ = run_schedule(lam, sigma2, ph2, m0=m_start, **kw)
    return float(r1[-1] / r2[-1])
