"""Seesaw (Algorithm 1) as a first-class runtime object.

A :class:`SeesawPlan` is the compiled form of a token-indexed LR×batch
schedule: an ordered list of :class:`Phase` (token budget, per-step LR
multiplier curve, batch size).  The trainer walks phases, re-jitting the
train step once per distinct batch size.

Guarantees enforced here (paper §3):
- token conservation: Σ phase tokens == total tokens, ramp or no ramp;
- the Lemma-4 feasibility constraint α ≥ √β (raises on violation);
- the equivalence invariant: a Seesaw plan and its reference step-decay
  plan have identical α√β product (Corollary 1).

``theoretical_speedup`` implements Lemma 1 (cosine → 2/π serial steps).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.core import schedules as S


@dataclass(frozen=True)
class Phase:
    index: int
    start_tokens: float
    end_tokens: float
    lr_scale: float              # multiplier on base_lr during this phase
    batch_size: int              # global batch (sequences)

    @property
    def tokens(self) -> float:
        return self.end_tokens - self.start_tokens

    def n_steps(self, seq_len: int) -> int:
        """Standalone estimate from this phase's ideal token span.
        ``SeesawPlan.steps_per_phase`` is the AUTHORITATIVE allocation:
        it threads a token carry across phases so the plan total is
        conserved exactly, and a single phase's count there can differ
        from this rounding by ±1.  Anything that must agree with the
        loader / device LR (chunking, resume, realized boundaries)
        must use the plan-level method; this per-phase estimate is for
        isolated reporting only."""
        return max(int(round(self.tokens / (self.batch_size * seq_len))), 1)


@dataclass(frozen=True)
class SeesawPlan:
    base_lr: float
    warmup_tokens: float
    total_tokens: float
    phases: List[Phase]
    alpha: float                 # LR cut factor per phase boundary
    beta: float                  # batch multiplier per phase boundary
    kind: str = "seesaw"

    # ------------------------------------------------------------------ #
    def steps_per_phase(self, seq_len: int) -> List[int]:
        """Allocate whole steps to phases with a token carry so that the
        total token budget is conserved exactly (±1 step) regardless of
        the ramp — the equal-FLOPs comparison depends on this."""
        out = []
        carry = 0.0
        for i, p in enumerate(self.phases):
            tok_per_step = p.batch_size * seq_len
            avail = p.tokens + carry
            if i == len(self.phases) - 1:
                steps = int(math.floor(avail / tok_per_step + 0.5))
            else:
                steps = int(avail // tok_per_step)
            out.append(steps)
            carry = avail - steps * tok_per_step
        return out

    def total_steps(self, seq_len: int) -> int:
        return sum(self.steps_per_phase(seq_len))

    def total_tokens_scheduled(self, seq_len: int) -> float:
        return sum(s * p.batch_size * seq_len for s, p in
                   zip(self.steps_per_phase(seq_len), self.phases))

    def batch_sizes(self) -> List[int]:
        return [p.batch_size for p in self.phases]

    def merged_segments(self, seq_len: int):
        """Adjacent same-batch-size phases merged into contiguous
        segments: ``[(batch_size, [(phase, n_steps), ...]), ...]``.

        Because the device LR is token/step-indexed (not phase-indexed),
        a fused chunk may legally span a phase boundary as long as the
        batch size — and therefore the compiled program shape — does not
        change.  'step' plans (β=1) collapse to a single segment; a
        clamped ramp (``max_batch_size``) merges its saturated tail.
        Phases whose realized step count is zero are dropped."""
        segs: List = []
        for phase, n in zip(self.phases, self.steps_per_phase(seq_len)):
            if n <= 0:
                continue
            if segs and segs[-1][0] == phase.batch_size:
                segs[-1][1].append((phase, n))
            else:
                segs.append((phase.batch_size, [(phase, n)]))
        return segs

    def phase_at_tokens(self, tok: float) -> Phase:
        for p in self.phases:
            if tok < p.end_tokens:
                return p
        return self.phases[-1]

    def realized_phase_at(self, tok: float, seq_len: int) -> Phase:
        """Phase of the step that *starts* at ``tok``, under the
        step-quantized boundaries of :meth:`steps_per_phase` — what the
        loader and the engine's device LR actually use (the ideal
        ``end_tokens`` can sit up to a step's carry past the realized
        boundary)."""
        tok = float(tok)
        for p, n in zip(self.phases, self.steps_per_phase(seq_len)):
            span = n * p.batch_size * seq_len
            if tok < span - 0.5:
                return p
            tok -= span
        return self.phases[-1]

    def lr_at(self, tok: float) -> float:
        if tok < self.warmup_tokens:
            return self.base_lr * tok / max(self.warmup_tokens, 1.0)
        return self.base_lr * self.phase_at_tokens(tok).lr_scale

    def validate(self):
        assert self.phases, "empty plan"
        tol = 1e-6 * self.total_tokens
        assert abs(self.phases[-1].end_tokens - self.total_tokens) <= tol
        for p in self.phases:
            if p.end_tokens - p.start_tokens <= 0:
                raise ValueError(
                    f"phase {p.index} has non-positive token span "
                    f"[{p.start_tokens}, {p.end_tokens}) — the cut "
                    f"points are out of order or past total_tokens")
        for a, b in zip(self.phases, self.phases[1:]):
            assert abs(a.end_tokens - b.start_tokens) <= tol
            assert b.batch_size >= a.batch_size, "batch must not shrink"
        # Lemma-4 feasibility — except for 'naive-ramp', which is the
        # paper's DELIBERATELY divergent Figure-5 baseline (batch ×β
        # with no LR cut); it still gets the structural checks above
        if (self.kind != "naive-ramp" and self.beta > 1.0
                and self.alpha < math.sqrt(self.beta) - 1e-9):
            raise ValueError(
                f"divergent ramp (Lemma 4): alpha={self.alpha} < "
                f"sqrt(beta)={math.sqrt(self.beta)}")
        return self

    # -- live extension (adaptive Seesaw) ------------------------------- #
    def extend_at(self, cut_tokens: int, *, seq_len: int,
                  max_batch_size: Optional[int] = None) -> "SeesawPlan":
        """A new plan with the last phase cut at ``cut_tokens`` and a
        fresh (LR ÷ α, batch × β) phase appended to ``total_tokens`` —
        how an :class:`repro.core.adaptive.AdaptiveSeesaw` cut turns
        the plan into a live object mid-run.

        ``cut_tokens`` must land on a *realized step boundary* of the
        last phase (the trainer fires cuts at chunk boundaries, which
        are step boundaries by construction), strictly inside it — so
        the re-chunked loader, the runtime LR table and the checkpoint
        resume all agree on the same integer boundary.  The extended
        plan is re-validated (token conservation, ordering, Lemma 4).
        ``max_batch_size`` clamps the appended phase's batch (the ramp
        saturates; the LR keeps cutting)."""
        last = self.phases[-1]
        cut = int(cut_tokens)
        realized_start = 0
        for p, n in zip(self.phases[:-1],
                        self.steps_per_phase(seq_len)[:-1]):
            realized_start += n * p.batch_size * seq_len
        tok_per_step = last.batch_size * seq_len
        if not realized_start < cut < self.total_tokens:
            raise ValueError(
                f"cut at {cut} tokens is outside the open last phase "
                f"({realized_start}, {self.total_tokens:.0f})")
        if (cut - realized_start) % tok_per_step:
            raise ValueError(
                f"cut at {cut} tokens is not on a step boundary of "
                f"phase {last.index} (B={last.batch_size}, "
                f"seq_len={seq_len}: {tok_per_step} tokens/step)")
        new_b = int(round(last.batch_size * self.beta))
        if max_batch_size:
            new_b = min(new_b, max_batch_size)
        phases = list(self.phases[:-1])
        phases.append(dataclasses.replace(last, end_tokens=float(cut)))
        phases.append(Phase(last.index + 1, float(cut),
                            self.total_tokens,
                            last.lr_scale / self.alpha, new_b))
        return dataclasses.replace(self, phases=phases).validate()


def divergence_risk(alpha: float, beta: float) -> bool:
    """Lemma 4: the effective NSGD LR scales by (√β/α) per cut — a ramp
    with α < √β grows the effective LR without bound."""
    return alpha < math.sqrt(beta) - 1e-12


def effective_lr_ratio(alpha: float, beta: float, k: int) -> float:
    """η̃_k/η̃_0 for NSGD under Assumption 2:  (√β/α)^k."""
    return (math.sqrt(beta) / alpha) ** k


# --------------------------------------------------------------------- #
# plan builders
# --------------------------------------------------------------------- #

def build_plan(*, kind: str, base_lr: float, total_tokens: float,
               warmup_frac: float, b0: int, alpha: float = 2.0,
               beta: Optional[float] = None, n_cuts: int = 8,
               max_batch_size: Optional[int] = None,
               cut_tokens: Optional[Sequence[float]] = None,
               quarter_cosine: bool = True) -> SeesawPlan:
    """Build the phase plan for any of the paper's schedulers.

    kind:
      'cosine'        — single phase, batch B0, cosine LR (continuous;
                        lr_scale recorded as 1.0, trainer evaluates the
                        continuous curve).
      'step'          — the α-step-decay approximation of cosine (β=1).
      'seesaw'        — Algorithm 1: cut √α, batch ×α  (α_s=√α, β=α keeps
                        α_s√β = α = the step-decay's α·√1 product).
      'seesaw-general'— arbitrary (α, β) on the equivalence line
                        (validated against Lemma 4).
      'constant'      — constant LR, constant batch (Figure 5 baseline).
      'naive-ramp'    — constant LR, batch ×β per cut (Figure 5 blue).
      'adaptive-seesaw' — budget-free plateau-triggered Seesaw: starts
                        as a single (LR 1.0, batch B0) phase;
                        :meth:`SeesawPlan.extend_at` appends a
                        (÷√α LR, ×α batch) phase each time the
                        :class:`repro.core.adaptive.AdaptiveSeesaw`
                        controller fires (``total_tokens`` is the run
                        horizon, not a schedule input).

    Every kind is validated (token conservation, phase ordering; the
    Lemma-4 feasibility check no-ops when β ≤ 1).  An explicit
    ``cut_tokens`` list must be strictly increasing and lie strictly
    inside ``(warmup, total_tokens)`` — malformed cuts raise instead
    of silently building a plan with dropped or reordered phases.
    """
    warmup = warmup_frac * total_tokens
    if cut_tokens is None:
        cut_tokens = S.cosine_cut_points(total_tokens, warmup, alpha,
                                         n_cuts, quarter=quarter_cosine)
    elif kind not in ("cosine", "adaptive-seesaw"):
        explicit = [float(c) for c in cut_tokens]
        for a, b in zip(explicit, explicit[1:]):
            if b <= a:
                raise ValueError(
                    f"cut_tokens must be strictly increasing: "
                    f"{b} follows {a}")
        bad = [c for c in explicit if not warmup < c < total_tokens]
        if bad:
            raise ValueError(
                f"cut_tokens {bad} outside the open interval "
                f"(warmup={warmup:.0f}, total_tokens="
                f"{total_tokens:.0f})")
    cuts = [c for c in cut_tokens if warmup < c < total_tokens]

    if kind == "cosine":
        phases = [Phase(0, 0.0, total_tokens, 1.0, b0)]
        return SeesawPlan(base_lr, warmup, total_tokens, phases,
                          alpha=1.0, beta=1.0, kind=kind).validate()

    if kind == "adaptive-seesaw":
        # cuts are decided at runtime by the plateau controller; the
        # plan records the per-cut (α_s=√α, β=α) factors extend_at
        # applies, keeping α_s√β = α (Corollary 1) like 'seesaw'
        phases = [Phase(0, 0.0, total_tokens, 1.0, b0)]
        return SeesawPlan(base_lr, warmup, total_tokens, phases,
                          alpha=math.sqrt(alpha), beta=alpha,
                          kind=kind).validate()

    if kind == "constant":
        lr_cut, b_mult = 1.0, 1.0
    elif kind == "step":
        lr_cut, b_mult = alpha, 1.0
    elif kind == "seesaw":
        lr_cut, b_mult = math.sqrt(alpha), alpha
    elif kind == "seesaw-general":
        assert beta is not None
        lr_cut, b_mult = alpha, beta
    elif kind == "naive-ramp":
        assert beta is not None
        lr_cut, b_mult = 1.0, beta
    else:
        raise ValueError(kind)

    bounds = [0.0] + list(cuts) + [total_tokens]
    phases = []
    b = float(b0)
    for i in range(len(bounds) - 1):
        bs = int(round(b))
        if max_batch_size:
            bs = min(bs, max_batch_size)
        phases.append(Phase(i, bounds[i], bounds[i + 1],
                            lr_cut ** (-i), bs))
        b *= b_mult
    # validate EVERY kind — 'step'/'constant'/'naive-ramp' used to skip
    # this, so malformed explicit cut lists built silently; the Lemma-4
    # check inside no-ops for β ≤ 1
    return SeesawPlan(base_lr, warmup, total_tokens, phases,
                      alpha=lr_cut, beta=b_mult, kind=kind).validate()


# --------------------------------------------------------------------- #
# Lemma 1
# --------------------------------------------------------------------- #

def theoretical_speedup() -> float:
    """Lemma 1: serial-step reduction of Seesaw vs quarter-cosine in the
    continuous limit = 1 − 2/π ≈ 0.3634."""
    return 1.0 - 2.0 / math.pi


def measured_speedup(plan_seesaw: SeesawPlan, plan_ref: SeesawPlan,
                     seq_len: int) -> float:
    s, r = plan_seesaw.total_steps(seq_len), plan_ref.total_steps(seq_len)
    return 1.0 - s / r


def continuous_step_fraction(n_cuts: int, alpha: float = 2.0) -> float:
    """Discrete-plan approximation of ∫cos: with cut points where a
    quarter-cosine crosses α^{-k}, Seesaw's per-phase batch grows ×α, so
    steps shrink ×α per phase; the fraction of baseline steps is
    Σ w_k α^{-k} with w_k the token fraction of phase k."""
    cuts = S.cosine_cut_points(1.0, 0.0, alpha, n_cuts, quarter=True)
    bounds = [0.0] + cuts + [1.0]
    frac = 0.0
    for k in range(len(bounds) - 1):
        frac += (bounds[k + 1] - bounds[k]) * alpha ** (-k)
    return frac
