"""Learning-rate schedules and the step-decay approximation of cosine.

The paper (§3.2) approximates cosine decay by a step-decay that cuts the
LR by α at the token counts where the cosine would have decayed by α;
Seesaw then replaces each α-cut with (√α-cut, ×α batch).  All schedule
math is in *tokens* so it is batch-size independent — exactly what makes
the ramp a drop-in.
"""
from __future__ import annotations

import math
from typing import Callable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np


def cosine_lr(base_lr: float, total_tokens: float, warmup_tokens: float,
              final_frac: float = 0.0) -> Callable[[float], float]:
    """LR as a function of tokens consumed (paper: η(t)=η₀cos(πt/2T) after
    10% warmup; we use the conventional half-cosine to final_frac and
    also provide the paper's quarter-cosine via ``quarter=True`` in
    :func:`cosine_cut_points`).  The curve is continuous, so the
    optional ``step`` index (used by :func:`piecewise_lr` for exact cut
    placement) is accepted but ignored."""

    def lr(tok, step=None):
        tok = jnp.asarray(tok, jnp.float32)
        warm = base_lr * tok / jnp.maximum(warmup_tokens, 1.0)
        prog = jnp.clip((tok - warmup_tokens)
                        / jnp.maximum(total_tokens - warmup_tokens, 1.0),
                        0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(tok < warmup_tokens, warm, base_lr * cos)

    return lr


def quarter_cosine_lr(base_lr: float, total_tokens: float,
                      warmup_tokens: float) -> Callable[[float], float]:
    """The paper's Lemma-1 form: η(t) = η₀ cos(π t / 2T) (decays to 0).
    Continuous — the optional ``step`` index is accepted but ignored."""

    def lr(tok, step=None):
        tok = jnp.asarray(tok, jnp.float32)
        warm = base_lr * tok / jnp.maximum(warmup_tokens, 1.0)
        prog = jnp.clip((tok - warmup_tokens)
                        / jnp.maximum(total_tokens - warmup_tokens, 1.0),
                        0.0, 1.0)
        return jnp.where(tok < warmup_tokens, warm,
                         base_lr * jnp.cos(0.5 * jnp.pi * prog))

    return lr


def cosine_cut_points(total_tokens: float, warmup_tokens: float,
                      alpha: float, n_cuts: int,
                      quarter: bool = True) -> List[float]:
    """Token counts where the cosine schedule's LR first falls below
    η₀/α^k, k = 1..n_cuts — the ``S`` array fed to Seesaw (Algorithm 1).

    quarter=True uses η₀cos(πt/2T) (paper Lemma 1); else half-cosine.
    """
    span = total_tokens - warmup_tokens
    cuts = []
    for k in range(1, n_cuts + 1):
        target = alpha ** (-k)
        if quarter:
            # cos(pi/2 * p) = target  →  p = 2/pi * acos(target)
            p = 2.0 / math.pi * math.acos(target)
        else:
            # 0.5(1+cos(pi p)) = target
            p = math.acos(2 * target - 1) / math.pi
        tok = warmup_tokens + p * span
        if tok < total_tokens:
            cuts.append(tok)
    return cuts


def step_decay_lr(base_lr: float, cut_tokens: Sequence[float],
                  alpha: float, warmup_tokens: float) -> Callable:
    """Step-decay: LR = η₀ α^{-k} after the k-th cut (token-indexed)."""
    cuts = np.asarray(list(cut_tokens), np.float32)

    def lr(tok, step=None):
        tok = jnp.asarray(tok, jnp.float32)
        k = jnp.sum(tok[..., None] >= cuts, axis=-1) if cuts.size \
            else jnp.zeros_like(tok)
        warm = base_lr * tok / jnp.maximum(warmup_tokens, 1.0)
        return jnp.where(tok < warmup_tokens, warm,
                         base_lr * (alpha ** (-k.astype(jnp.float32))))

    return lr


def piecewise_lr(base_lr: float, warmup_tokens: float,
                 phase_ends: Sequence[float],
                 phase_scales: Sequence[float],
                 phase_end_steps: Optional[Sequence[int]] = None
                 ) -> Callable:
    """Device-side piecewise-constant LR: the traced form of
    ``SeesawPlan.lr_at``.  ``phase_ends[k]`` is the end-token count of
    phase k; the LR in phase k is ``base_lr * phase_scales[k]``.  The
    lookup is a sum of comparisons against a constant array, so the
    whole schedule lives inside the jitted train step — cosine, step
    and seesaw share one traced code path and no host LR computation
    happens per step.

    Cut selection comes in two exactness tiers.  The f32 token compare
    is exact only while token counts stay below 2^24 (one ulp of tok
    past that, and a cut can land one step early/late).  When
    ``phase_end_steps`` (the realized cumulative step count per phase)
    is given and the caller passes the global ``step`` index, the cut
    is selected by an exact int32 comparison instead; ``tok`` is then
    only used for the (continuous) warmup ramp, where a 1-ulp error is
    a ~1e-7 relative LR error, not a misplaced discontinuity.  A
    negative ``step`` (the engine's sentinel for "unknown") falls back
    to the token compare."""
    ends = jnp.asarray(np.asarray(phase_ends, np.float32))
    scales = jnp.asarray(np.asarray(phase_scales, np.float32))
    step_ends = (None if phase_end_steps is None
                 else jnp.asarray(np.asarray(phase_end_steps, np.int32)))

    def lr(tok, step=None):
        tok = jnp.asarray(tok, jnp.float32)
        k_tok = jnp.sum(tok >= ends[:-1])    # ≤ n-1 by construction
        if step is None or step_ends is None:
            k = k_tok
        else:
            step = jnp.asarray(step, jnp.int32)
            k = jnp.where(step >= 0,
                          jnp.sum(step >= step_ends[:-1]), k_tok)
        warm = base_lr * tok / jnp.maximum(warmup_tokens, 1.0)
        return jnp.where(tok < warmup_tokens, warm, base_lr * scales[k])

    return lr


def adaptive_piecewise_lr(base_lr: float,
                          warmup_tokens: float) -> Callable:
    """Runtime-table variant of :func:`piecewise_lr` for plans that are
    extended while the run is live (adaptive Seesaw).

    The phase table — realized cut steps, cut tokens and per-phase LR
    scales — arrives as *traced arguments* instead of compile-time
    constants, so firing a cut changes argument values, never the
    compiled program: the engine's one-executable-per-distinct-batch-
    size invariant survives dynamically-created phases (including a
    ``max_batch_size``-clamped ramp, where a cut changes the LR but not
    the batch size, i.e. not the executable).  Tables have a fixed
    width (max cuts + slack); unused cut slots are padded with
    ``INT32_MAX`` / ``+inf`` ends and repeat the last scale, so padding
    never selects a phase.

    Cut selection mirrors :func:`piecewise_lr`'s two exactness tiers:
    exact int32 compare on the global ``step`` when it is known
    (``step >= 0``), f32 token compare as the ``step < 0`` fallback
    (host probes) — exact only below 2^24 tokens."""

    def lr(tok, step, cut_steps, cut_tokens, scales):
        tok = jnp.asarray(tok, jnp.float32)
        step = jnp.asarray(step, jnp.int32)
        k_tok = jnp.sum(tok >= cut_tokens)
        k = jnp.where(step >= 0, jnp.sum(step >= cut_steps), k_tok)
        warm = base_lr * tok / jnp.maximum(warmup_tokens, 1.0)
        return jnp.where(tok < warmup_tokens, warm, base_lr * scales[k])

    return lr


def constant_lr(base_lr: float, warmup_tokens: float = 0.0) -> Callable:
    def lr(tok, step=None):
        tok = jnp.asarray(tok, jnp.float32)
        warm = base_lr * tok / jnp.maximum(warmup_tokens, 1.0)
        return jnp.where(tok < warmup_tokens, warm, base_lr)

    return lr
