from repro.core import adaptive, cbs, schedules, seesaw, theory
from repro.core.seesaw import (Phase, SeesawPlan, build_plan,
                               divergence_risk, effective_lr_ratio,
                               measured_speedup, theoretical_speedup)

__all__ = ["adaptive", "cbs", "schedules", "seesaw", "theory",
           "Phase", "SeesawPlan",
           "build_plan", "divergence_risk", "effective_lr_ratio",
           "measured_speedup", "theoretical_speedup"]
