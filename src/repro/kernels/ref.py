"""Pure-jnp oracles for every Pallas kernel — ground truth for the
shape/dtype sweep tests (assert_allclose against these)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,H,S,hd); k,v: (B,Hkv,S,hd).  Naive softmax attention."""
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    """x: (..., d)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def ssd_ref(x, dt, A, Bm, Cm, D):
    """Naive sequential SSD recurrence (the definition).

    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm, Cm: (B,S,N); D: (H,).
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t ⊗ x_t ;  y_t = C_t·h_t + D x_t.
    """
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    B32 = Bm.astype(jnp.float32)
    C32 = Cm.astype(jnp.float32)

    def step(h, t):
        a = jnp.exp(dt32[:, t] * A)                       # (B,H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt32[:, t], B32[:, t],
                         x32[:, t])
        h = h * a[:, :, None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", h, C32[:, t])
        y = y + D[None, :, None] * x32[:, t]
        return h, y

    h = jnp.zeros((Bsz, H, Pd, N), jnp.float32)
    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1)                            # (B,S,H,P)
    return y.astype(x.dtype), h
