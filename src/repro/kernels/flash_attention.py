"""Flash attention as a Pallas TPU kernel.

Grid: (batch·heads, q-blocks, k-blocks) — k is the innermost (fastest)
grid dim, so the online-softmax running stats (m, l, acc) live in VMEM
scratch across k iterations; block shapes are MXU-aligned (128 where the
sequence allows).  GQA is handled in the K/V BlockSpec index_map (query
head h reads kv head h // group) — no materialized repeat.

VMEM budget per step: q(bq·hd) + k,v(bk·hd) + acc(bq·hd) + s(bq·bk),
all f32 ⇒ with bq=bk=128, hd=128: ~0.4 MB, well inside ~16 MB VMEM.
Causal masking: fully-masked k-blocks are skipped via pl.when (halves
the work vs the XLA chunked-scan baseline — see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, block_q: int, block_k: int,
                  n_k: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T * sm_scale                      # (bq, bk)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v

    if causal:
        # a k-block is fully masked iff its first key position exceeds
        # the last query position of this q-block
        pl.when(ki * block_k <= qi * block_q + block_q - 1)(compute)
    else:
        compute()

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, H, S, hd); k, v: (B, Hkv, S, hd) with H % Hkv == 0.
    Returns (B, H, S, hd)."""
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0
    G = H // Hkv
    sm_scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_q, n_k = S // block_q, S // block_k

    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * Hkv, S, hd)
    vf = v.reshape(B * Hkv, S, hd)

    def kv_index(bh, qi, ki):
        b = bh // H
        hkv = (bh % H) // G
        return (b * Hkv + hkv, ki, 0)

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, block_q=block_q,
        block_k=block_k, n_k=n_k, causal=causal)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)
