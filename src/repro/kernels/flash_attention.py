"""Flash attention as a Pallas TPU kernel, with a custom-VJP backward.

Forward grid: (batch·heads, q-blocks, k-blocks) — k is the innermost
(fastest) grid dim, so the online-softmax running stats (m, l, acc) live
in VMEM scratch across k iterations; block shapes are MXU-aligned (128
where the sequence allows).  GQA is handled in the K/V BlockSpec
index_map (query head h reads kv head h // group) — no materialized
repeat.  Alongside the output the forward emits the log-sum-exp rows
``lse = m + log(l)`` that the backward needs to rebuild probabilities.

Backward is the standard flash recompute scheme — no (S, S) tensor is
ever materialized:

- ``dq`` kernel, grid (B·H, q-blocks, k-blocks) with a (bq, hd) VMEM
  accumulator: p = exp(s − lse); ds = p·(do·vᵀ − Δ)·scale; dq += ds·k,
  where Δ = rowsum(do ⊙ o) is computed once in XLA.
- ``dk/dv`` kernel, grid (B·H, k-blocks, q-blocks) with (bk, hd)
  accumulators: dv += pᵀ·do and dk += dsᵀ·q.  GQA runs this at full
  query-head resolution, then the per-group sum reduces (B, Hkv, G, …)
  → (B, Hkv, …) in XLA.

Causal masking skips fully-masked blocks via pl.when in both passes.

VMEM budget per step: q(bq·hd) + k,v(bk·hd) + acc(bq·hd) + s(bq·bk),
all f32 ⇒ with bq=bk=128, hd=128: ~0.4 MB, well inside ~16 MB VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _validate_blocks(S: int, block_q: int, block_k: int):
    """Raise a clear error for block/sequence mismatches instead of an
    opaque Pallas lowering failure (empty or out-of-range grid)."""
    if S < 1:
        raise ValueError(f"flash_attention: sequence length {S} < 1")
    if block_q < 1 or block_k < 1:
        raise ValueError(
            f"flash_attention: block sizes must be >= 1, got "
            f"block_q={block_q}, block_k={block_k}")
    if S % block_q or S % block_k:
        raise ValueError(
            f"flash_attention: sequence length {S} is not a multiple of "
            f"block_q={block_q} / block_k={block_k}; pick blocks that "
            f"divide the sequence (or pad it — "
            f"repro.kernels.backend.attention pads causal sequences "
            f"automatically)")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                  acc_scr, *, sm_scale: float, block_q: int, block_k: int,
                  n_k: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T * sm_scale                      # (bq, bk)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v

    if causal:
        # a k-block is fully masked iff its first key position exceeds
        # the last query position of this q-block
        pl.when(ki * block_k <= qi * block_q + block_q - 1)(compute)
    else:
        compute()

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_scr[...] + jnp.log(jnp.maximum(l, 1e-30))


def _kv_index(H: int, Hkv: int, G: int):
    """Index map for K/V operands: the GQA head fold plus the kv-block
    index, which is the LAST grid argument (ki is innermost in the
    forward/dq grids; the dkv call site reorders its args to match)."""
    def kv_index(bh, i, j):
        b = bh // H
        hkv = (bh % H) // G
        return (b * Hkv + hkv, j, 0)
    return kv_index


def _flash_fwd(q, k, v, *, causal: bool, block_q: int, block_k: int,
               interpret: bool):
    """Returns (out (B,H,S,hd), lse (B·H, S) f32)."""
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    sm_scale = 1.0 / math.sqrt(hd)
    n_q, n_k = S // block_q, S // block_k

    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * Hkv, S, hd)
    vf = v.reshape(B * Hkv, S, hd)
    kv_index = _kv_index(H, Hkv, G)

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, block_q=block_q,
        block_k=block_k, n_k=n_k, causal=causal)

    out, lse = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
            jax.ShapeDtypeStruct((B * H, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd), lse


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, dq_scr, *, sm_scale: float, block_q: int,
                   block_k: int, n_k: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    def compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)          # (bq, hd)
        lse = lse_ref[0]                            # (bq,) f32
        delta = delta_ref[0]                        # (bq,) f32
        s = q @ k.T * sm_scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])               # masked entries → 0
        dp = do @ v.T                               # (bq, bk)
        ds = p * (dp - delta[:, None]) * sm_scale
        dq_scr[...] += ds @ k

    if causal:
        pl.when(ki * block_k <= qi * block_q + block_q - 1)(compute)
    else:
        compute()

    @pl.when(ki == n_k - 1)
    def _finish():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_scr, dv_scr, *, sm_scale: float,
                    block_q: int, block_k: int, n_q: int, causal: bool):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    def compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)          # (bq, hd)
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = q @ k.T * sm_scale                      # (bq, bk)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dv_scr[...] += p.T @ do                     # (bk, hd)
        dp = do @ v.T
        ds = p * (dp - delta[:, None]) * sm_scale
        dk_scr[...] += ds.T @ q

    if causal:
        # a q-block contributes iff its last query can see this k-block
        pl.when(qi * block_q + block_q - 1 >= ki * block_k)(compute)
    else:
        compute()

    @pl.when(qi == n_q - 1)
    def _finish():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _flash_bwd(q, k, v, out, lse, do, *, causal: bool, block_q: int,
               block_k: int, interpret: bool):
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    sm_scale = 1.0 / math.sqrt(hd)
    n_q, n_k = S // block_q, S // block_k

    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * Hkv, S, hd)
    vf = v.reshape(B * Hkv, S, hd)
    dof = do.reshape(B * H, S, hd)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1).reshape(B * H, S)
    kv_index = _kv_index(H, Hkv, G)

    q_spec = pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0))
    row_spec = pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, sm_scale=sm_scale,
                          block_q=block_q, block_k=block_k, n_k=n_k,
                          causal=causal),
        grid=(B * H, n_q, n_k),
        in_specs=[
            q_spec,
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
            q_spec,
            row_spec,
            row_spec,
        ],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)

    # dk/dv grid iterates q innermost; q-indexed operands read block qi
    # (grid position 2), kv-indexed operands block ki (position 1)
    qT_spec = pl.BlockSpec((1, block_q, hd), lambda bh, ki, qi: (bh, qi, 0))
    rowT_spec = pl.BlockSpec((1, block_q), lambda bh, ki, qi: (bh, qi))
    kvT_index = _kv_index(H, Hkv, G)
    k_spec = pl.BlockSpec((1, block_k, hd),
                          lambda bh, ki, qi: kvT_index(bh, qi, ki))
    dkv_spec = pl.BlockSpec((1, block_k, hd), lambda bh, ki, qi: (bh, ki, 0))

    dk_h, dv_h = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, sm_scale=sm_scale,
                          block_q=block_q, block_k=block_k, n_q=n_q,
                          causal=causal),
        grid=(B * H, n_k, n_q),
        in_specs=[qT_spec, k_spec, k_spec, qT_spec, rowT_spec, rowT_spec],
        out_specs=[dkv_spec, dkv_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, S, hd), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, hd), jnp.float32),
            pltpu.VMEM((block_k, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)

    # GQA: per-query-head dk/dv reduce over the group in XLA
    dk = dk_h.reshape(B, Hkv, G, S, hd).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(B, Hkv, G, S, hd).sum(axis=2).astype(v.dtype)
    return dq.reshape(B, H, S, hd), dk, dv


@functools.lru_cache(maxsize=None)
def _flash_with_vjp(causal: bool, block_q: int, block_k: int,
                    interpret: bool):
    """custom_vjp flash attention specialized on the static config; the
    lru_cache keeps the jit cache keyed on one stable callable per
    (causal, blocks, interpret) combination."""

    @jax.custom_vjp
    def attn(q, k, v):
        out, _ = _flash_fwd(q, k, v, causal=causal, block_q=block_q,
                            block_k=block_k, interpret=interpret)
        return out

    def fwd(q, k, v):
        out, lse = _flash_fwd(q, k, v, causal=causal, block_q=block_q,
                              block_k=block_k, interpret=interpret)
        return out, (q, k, v, out, lse)

    def bwd(res, do):
        q, k, v, out, lse = res
        return _flash_bwd(q, k, v, out, lse, do, causal=causal,
                          block_q=block_q, block_k=block_k,
                          interpret=interpret)

    attn.defvjp(fwd, bwd)
    return attn


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, H, S, hd); k, v: (B, Hkv, S, hd) with H % Hkv == 0.
    Returns (B, H, S, hd).  Differentiable (custom-VJP flash backward);
    the sequence must be a multiple of both block sizes — the backend
    registry (repro.kernels.backend.attention) pads causal sequences
    automatically."""
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    if Hkv < 1 or H % Hkv:
        raise ValueError(
            f"flash_attention: n_heads={H} not a multiple of "
            f"n_kv_heads={Hkv}")
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    _validate_blocks(S, block_q, block_k)
    return _flash_with_vjp(bool(causal), int(block_q), int(block_k),
                           bool(interpret))(q, k, v)
