"""Fused RMSNorm as a Pallas TPU kernel.

Grid over row-blocks; each step loads a (block_rows, d) tile + the (d,)
scale into VMEM, does the reduction and the scale multiply in one pass
(one HBM read + one write vs three for the unfused mean/rsqrt/mul
sequence — RMSNorm is memory-bound, so fusion ≈ 3× on the HBM term).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # (rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) \
        * (1.0 + s_ref[...].astype(jnp.float32))
    o_ref[...] = out.astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n = xf.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
