"""Fused RMSNorm as a Pallas TPU kernel, with a custom-VJP backward.

Grid over row-blocks; each step loads a (block_rows, d) tile + the (d,)
scale into VMEM, does the reduction and the scale multiply in one pass
(one HBM read + one write vs three for the unfused mean/rsqrt/mul
sequence — RMSNorm is memory-bound, so fusion ≈ 3× on the HBM term).

Backward recomputes rr = rsqrt(var + eps) from x (cheaper than saving
it: one fma per element vs an extra HBM round-trip):

    x̂  = x · rr
    gs = g · (1 + scale)
    dx = rr · (gs − x̂ · mean(gs · x̂, −1))
    dscale = Σ_rows g · x̂

dscale is accumulated as one (1, d) partial per row-block, written to a
(n_blocks, d) f32 output and summed in XLA — no cross-block scratch
carry, so the grid stays embarrassingly parallel.  Zero-padded tail
rows contribute exactly zero to both dx and the dscale partials.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # (rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) \
        * (1.0 + s_ref[...].astype(jnp.float32))
    o_ref[...] = out.astype(o_ref.dtype)


def _rmsnorm_bwd_kernel(x_ref, s_ref, g_ref, dx_ref, dscale_ref, *,
                        eps: float):
    x = x_ref[...].astype(jnp.float32)                    # (rows, d)
    g = g_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    rr = jax.lax.rsqrt(var + eps)
    xh = x * rr
    gs = g * (1.0 + s_ref[...].astype(jnp.float32))
    proj = jnp.mean(gs * xh, axis=-1, keepdims=True)
    dx_ref[...] = (rr * (gs - xh * proj)).astype(dx_ref.dtype)
    dscale_ref[...] = jnp.sum(g * xh, axis=0, keepdims=True)


def _pallas_fwd(x, scale, *, eps, block_rows, interpret):
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n = xf.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)


def _pallas_bwd(x, scale, g, *, eps, block_rows, interpret):
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    gf = g.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        gf = jnp.pad(gf, ((0, pad), (0, 0)))
    n = xf.shape[0] // block_rows

    dx, dscale_parts = pl.pallas_call(
        functools.partial(_rmsnorm_bwd_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xf.shape, x.dtype),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
        ],
        interpret=interpret,
    )(xf, scale, gf)
    if pad:
        dx = dx[:rows]
    dscale = jnp.sum(dscale_parts, axis=0).astype(scale.dtype)
    return dx.reshape(orig_shape), dscale


@functools.lru_cache(maxsize=None)
def _rmsnorm_with_vjp(eps: float, block_rows: int, interpret: bool):
    """custom_vjp rmsnorm specialized on the static config (one stable
    callable per (eps, block_rows, interpret) keeps the jit cache keyed
    consistently)."""

    @jax.custom_vjp
    def norm(x, scale):
        return _pallas_fwd(x, scale, eps=eps, block_rows=block_rows,
                           interpret=interpret)

    def fwd(x, scale):
        out = _pallas_fwd(x, scale, eps=eps, block_rows=block_rows,
                          interpret=interpret)
        return out, (x, scale)

    def bwd(res, g):
        x, scale = res
        return _pallas_bwd(x, scale, g, eps=eps, block_rows=block_rows,
                           interpret=interpret)

    norm.defvjp(fwd, bwd)
    return norm


def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., d); scale: (d,).  Differentiable (custom-VJP backward
    recomputing the rsqrt from x)."""
    return _rmsnorm_with_vjp(float(eps), int(block_rows),
                             bool(interpret))(x, scale)
