"""Kernel backend registry: one switch for every hot-path op.

Routes attention, RMSNorm, and the SSD chunk scan through a selectable
backend:

  ``xla``              — the stock jnp/lax paths the models have always
                         run (``models.attention.chunked_attention``,
                         ``ref.rmsnorm_ref``, ``models.mamba2.
                         ssd_chunked``); the default.
  ``pallas``           — the fused Pallas TPU kernels in this package,
                         compiled natively (TPU only).
  ``pallas_interpret`` — the same kernels under ``interpret=True``, so
                         the full training stack runs (and CI tests) on
                         CPU with identical kernel semantics.

The backend is threaded from ``ModelConfig.kernel_backend`` (or the
``--kernel-backend`` launcher flag via ``RunConfig``) down through the
model forward passes, so the fused K-step executable in
``train.engine`` compiles against the chosen kernels.  All Pallas ops
carry custom-VJP backwards (see flash_attention / rmsnorm / ssd), so
every backend is trainable, not just runnable.

Ops here take the MODELS' tensor layouts (attention: (B, S, H, hd)),
not the kernels' — the registry owns the transposes and the pad/slice
bookkeeping so call sites stay layout-agnostic.

The default can also be set process-wide with the
``REPRO_KERNEL_BACKEND`` env var (explicit arguments win).
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import paged as _paged
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd as _ssd

BACKENDS = ("xla", "pallas", "pallas_interpret")


def resolve(backend: str | None = None) -> str:
    """Resolve an explicit/env/default backend name, validating it."""
    if backend is None:
        backend = os.environ.get("REPRO_KERNEL_BACKEND") or "xla"
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of "
            f"{BACKENDS}")
    return backend


def _interp(backend: str) -> bool:
    return backend == "pallas_interpret"


def rmsnorm(x, scale, *, eps: float = 1e-5, backend: str | None = None,
            block_rows: int = 256):
    """x: (..., d); scale: (d,).  The ``xla`` entry is
    ``ref.rmsnorm_ref`` — the single source of truth that
    ``models.layers.rmsnorm`` also delegates to."""
    backend = resolve(backend)
    if backend == "xla":
        return _ref.rmsnorm_ref(x, scale, eps)
    return _rn.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                       interpret=_interp(backend))


def attention(q, k, v, *, causal: bool = True,
              backend: str | None = None, block_q: int = 128,
              block_k: int = 128):
    """Self-attention in the models' layout: q (B, S, H, hd),
    k/v (B, S, Hkv, hd) → (B, S, H, hd).

    The Pallas flash kernel needs S to divide the block sizes; causal
    sequences are zero-padded up to the next block multiple (padded
    keys sit at positions > every real query, so the causal mask zeroes
    them — outputs and gradients for real rows are unaffected, and the
    padded query rows are sliced off).  Non-causal ragged tails would
    attend to the padding, so they fall back to the XLA path instead.
    """
    backend = resolve(backend)
    S = q.shape[1]
    if backend != "xla":
        bq, bk = min(block_q, S), min(block_k, S)
        pad = max((-S) % bq, (-S) % bk)
        # pad to a common multiple of both blocks (bq, bk are powers of
        # two in practice; lcm = max when one divides the other)
        Sp = S + pad
        while Sp % bq or Sp % bk:
            Sp += 1
        pad = Sp - S
        if pad and not causal:
            backend = "xla"  # padded keys would be attended to
        else:
            qt = jnp.swapaxes(q, 1, 2)
            kt = jnp.swapaxes(k, 1, 2)
            vt = jnp.swapaxes(v, 1, 2)
            if pad:
                cfg = ((0, 0), (0, 0), (0, pad), (0, 0))
                qt = jnp.pad(qt, cfg)
                kt = jnp.pad(kt, cfg)
                vt = jnp.pad(vt, cfg)
            out = _fa.flash_attention(
                qt, kt, vt, causal=causal, block_q=bq, block_k=bk,
                interpret=_interp(backend))
            if pad:
                out = out[:, :, :S]
            return jnp.swapaxes(out, 1, 2)
    from repro.models.attention import chunked_attention  # import cycle
    return chunked_attention(q, k, v, causal=causal)


def paged_decode_attention(q, k, v, lengths, *, backend: str | None = None,
                           chunk: int = 4096, block_k: int = 128):
    """Ragged single-token decode attention over a gathered paged KV
    window (the serving hot path; see ``repro.serving.cache``).

    q: (B, 1, H, hd) — the new token's query, sitting at per-request
    absolute position ``lengths[b]``.  k, v: (B, Skv, Hkv, hd) gathered
    page windows whose slot ``s`` holds absolute position ``s``.  Valid
    keys for request b are slots 0..lengths[b] inclusive (slot
    ``lengths[b]`` is the token just written); everything later — page
    remainders, stale slots from evicted requests, zero padding — sits at
    positions beyond the causal reach and is masked by the same
    zero-padding convention as ``attention``, so it contributes exactly
    zero on every backend.  Returns (B, 1, H, hd)."""
    backend = resolve(backend)
    if backend == "xla":
        # the dense decode path's op, with the scalar offset/length
        # promoted to per-request arrays — identical arithmetic, so the
        # paged lookup is bitwise against a dense cache of equal width
        from repro.models.attention import chunked_attention  # import cycle
        return chunked_attention(
            q, k, v, causal=True, q_offset=lengths[:, None],
            kv_len=(lengths + 1)[:, None, None], chunk=chunk)
    out = _paged.ragged_decode_attention(
        jnp.swapaxes(q, 1, 2)[:, :, 0], jnp.swapaxes(k, 1, 2),
        jnp.swapaxes(v, 1, 2), lengths, block_k=block_k,
        interpret=_interp(backend))
    return out[:, None]


def ssd(xh, dt, A, Bm, Cm, D, *, chunk: int = 128,
        backend: str | None = None):
    """Full SSD scan: xh (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N),
    D (H,) → (y (B,S,H,P), h_final (B,H,P,N)).  Same contract as
    ``models.mamba2.ssd_chunked`` with h0=None on every backend."""
    backend = resolve(backend)
    if backend == "xla":
        from repro.models.mamba2 import ssd_chunked  # import cycle
        return ssd_chunked(xh, dt, A, Bm, Cm, D, chunk=chunk)
    return _ssd.ssd_full(xh, dt, A, Bm, Cm, D, chunk=chunk,
                         interpret=_interp(backend))
