"""jit'd public wrappers for the Pallas kernels with automatic fallback.

On TPU the Pallas path compiles natively; elsewhere (this CPU container)
``interpret=True`` executes the kernel body for correctness validation.
``use_pallas=False`` (or the REPRO_NO_PALLAS env var) routes to the
pure-jnp reference — that is the path the distributed dry-run lowers.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd as _ssd


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def _interpret() -> bool:
    return not _on_tpu()


def flash_attention(q, k, v, *, causal: bool = True,
                    use_pallas: bool = True, block_q: int = 128,
                    block_k: int = 128):
    if not use_pallas or os.environ.get("REPRO_NO_PALLAS"):
        return _ref.attention_ref(q, k, v, causal=causal)
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interpret())


def rmsnorm(x, scale, *, eps: float = 1e-5, use_pallas: bool = True,
            block_rows: int = 256):
    if not use_pallas or os.environ.get("REPRO_NO_PALLAS"):
        return _ref.rmsnorm_ref(x, scale, eps)
    return _rn.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                       interpret=_interpret())


def ssd(xh, dt, A, Bm, Cm, D, *, chunk: int = 128,
        use_pallas: bool = True):
    if not use_pallas or os.environ.get("REPRO_NO_PALLAS"):
        return _ref.ssd_ref(xh, dt, A, Bm, Cm, D)
    return _ssd.ssd_full(xh, dt, A, Bm, Cm, D, chunk=chunk,
                         interpret=_interpret())
