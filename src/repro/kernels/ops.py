"""jit'd public wrappers for the Pallas kernels with automatic fallback.

Thin convenience layer over ``repro.kernels.backend``: the backend name
is picked automatically — ``pallas`` on TPU, ``pallas_interpret``
elsewhere (this CPU container executes the kernel bodies for
correctness validation).  ``use_pallas=False`` (or the REPRO_NO_PALLAS
env var) routes to the pure-jnp reference — that is the path the
distributed dry-run lowers.  Model code should thread an explicit
``ModelConfig.kernel_backend`` through ``repro.kernels.backend``
instead of calling these.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import backend as _backend
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def _auto_backend(use_pallas: bool) -> str:
    if not use_pallas or os.environ.get("REPRO_NO_PALLAS"):
        return "xla"
    return "pallas" if _on_tpu() else "pallas_interpret"


def flash_attention(q, k, v, *, causal: bool = True,
                    use_pallas: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """q: (B, H, S, hd) — the kernels' layout, unlike backend.attention."""
    b = _auto_backend(use_pallas)
    if b == "xla":
        return _ref.attention_ref(q, k, v, causal=causal)
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k,
                               interpret=_backend._interp(b))


def rmsnorm(x, scale, *, eps: float = 1e-5, use_pallas: bool = True,
            block_rows: int = 256):
    return _backend.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                            backend=_auto_backend(use_pallas))


def ssd(xh, dt, A, Bm, Cm, D, *, chunk: int = 128,
        use_pallas: bool = True):
    b = _auto_backend(use_pallas)
    if b == "xla":
        # historical ops semantics: the no-pallas fallback is the naive
        # reference scan, not the chunked XLA path backend.ssd uses
        return _ref.ssd_ref(xh, dt, A, Bm, Cm, D)
    return _backend.ssd(xh, dt, A, Bm, Cm, D, chunk=chunk, backend=b)
