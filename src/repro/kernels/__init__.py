from repro.kernels import flash_attention, ops, ref, rmsnorm, ssd

__all__ = ["flash_attention", "ops", "ref", "rmsnorm", "ssd"]
