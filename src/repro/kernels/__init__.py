from repro.kernels import (backend, flash_attention, ops, ref, rmsnorm,
                           ssd)

__all__ = ["backend", "flash_attention", "ops", "ref", "rmsnorm", "ssd"]
