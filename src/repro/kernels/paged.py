"""Ragged decode attention as a Pallas TPU kernel — the serving-side
counterpart of ``flash_attention``.

One query token per sequence (the token just written at position
``lengths[b]``) attends over a gathered page window k/v whose slot ``s``
holds absolute position ``s``.  Grid is (batch·heads, k-blocks) with the
online-softmax running stats (m, l, acc) in VMEM scratch across the
k iterations, exactly like the flash forward; GQA is folded into the K/V
BlockSpec index map (query head h reads kv head h // group).  Per-request
lengths sit in SMEM — the mask ``kpos <= lengths[b]`` implements the
repo's zero-padding convention: page remainders, stale slots from evicted
requests, and block padding all live at positions the causal reach never
touches, so they contribute exactly zero.

Fully-masked k-blocks (``ki·block_k > lengths[b]``) are skipped via
``pl.when`` — a request early in its decode reads only the pages it has
actually filled.  Decode is inference-only, so there is no backward.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ragged_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, block_k: int, n_k: int, scale: float):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ln = len_ref[0, 0]                              # this request's length

    def compute():
        q = q_ref[...].astype(jnp.float32)          # (1, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T * scale                         # (1, bk)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        s = jnp.where(kpos <= ln, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v

    # a k-block is fully masked iff its first key position exceeds the
    # request's causal reach (position `ln` holds the newest token)
    pl.when(ki * block_k <= ln)(compute)

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_scr[...]
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def ragged_decode_attention(q, k, v, lengths, *, block_k: int = 128,
                            interpret: bool = False):
    """q: (B, H, hd) single-token queries at per-request positions
    ``lengths``; k, v: (B, Hkv, Skv, hd) with H % Hkv == 0; lengths:
    (B,) int32 — valid keys for request b are slots 0..lengths[b]
    inclusive.  Returns (B, H, hd).  Skv is padded here to a block_k
    multiple (padding positions are always beyond every causal reach)."""
    B, H, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    if Hkv < 1 or H % Hkv:
        raise ValueError(
            f"ragged_decode_attention: n_heads={H} not a multiple of "
            f"n_kv_heads={Hkv}")
    G = H // Hkv
    block_k = min(block_k, Skv)
    pad = (-Skv) % block_k
    if pad:
        cfgp = ((0, 0), (0, 0), (0, pad), (0, 0))
        k = jnp.pad(k, cfgp)
        v = jnp.pad(v, cfgp)
        Skv += pad
    n_k = Skv // block_k
    scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(B * H, hd)
    kf = k.reshape(B * Hkv, Skv, hd)
    vf = v.reshape(B * Hkv, Skv, hd)
    lens = jnp.reshape(lengths, (B, 1)).astype(jnp.int32)

    def kv_index(bh, ki):
        b = bh // H
        hkv = (bh % H) // G
        return (b * Hkv + hkv, ki, 0)

    out = pl.pallas_call(
        functools.partial(_ragged_kernel, block_k=block_k, n_k=n_k,
                          scale=scale),
        grid=(B * H, n_k),
        in_specs=[
            pl.BlockSpec((1, hd), lambda bh, ki: (bh, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, 1), lambda bh, ki: (bh // H, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, hd), lambda bh, ki: (bh, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, lens)
    return out.reshape(B, H, hd)
