"""Chunked Mamba-2 SSD as a Pallas TPU kernel.

The hot part of SSD is the per-chunk quadratic form (masked C·Bᵀ kernel
against the chunk's values) plus the chunk-state contraction — both are
MXU matmuls over (Q × Q) and (Q × N) tiles.  The kernel computes, per
(batch, chunk, head) grid cell with everything VMEM-resident:

  y_intra[c]  = (CBᵀ ⊙ decay ⊙ dt) x[c]          (Q,P)
  S_chunk[c]  = Σ_j exp(T_c − cum_j) dt_j B_j ⊗ x_j   (N,P)
  T[c]        = Σ_j dt_j A                        scalar per head

The cheap cross-chunk recurrence (nc sequential steps on (N,P) states)
and the rank-1 inter-chunk correction stay in XLA — they are O(S·N·P)
vs the kernel's O(S·Q·(N+P)) and do not benefit from manual tiling.

VMEM per cell (Q=128, N=128, P=64, f32): x 32 KB + B/C 2·64 KB +
masks/CB 2·64 KB + outputs ~96 KB ⇒ < 0.5 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref,
                      t_ref):
    """Blocks: x (Q,P); dt (Q,); a (1,) scalar A for this head;
    b, c (Q,N); outputs y (Q,P), s (N,P), t (1,)."""
    x = x_ref[0].astype(jnp.float32)                      # (Q,P)
    dt = dt_ref[0].astype(jnp.float32)                    # (Q,)
    A = a_ref[0]
    Bm = b_ref[0].astype(jnp.float32)                     # (Q,N)
    Cm = c_ref[0].astype(jnp.float32)

    l = dt * A                                            # (Q,)
    cum = jnp.cumsum(l)                                   # inclusive
    T = cum[-1]

    Q = x.shape[0]
    diff = cum[:, None] - cum[None, :]                    # (i,j)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(jj <= ii, jnp.exp(diff), 0.0)
    CB = Cm @ Bm.T                                        # (Q,Q) MXU
    M = CB * decay * dt[None, :]
    y_ref[0] = (M @ x).astype(y_ref.dtype)                # (Q,P) MXU

    sdecay = jnp.exp(T - cum) * dt                        # (Q,)
    s_ref[0] = ((Bm * sdecay[:, None]).T @ x).astype(s_ref.dtype)
    t_ref[0] = T.astype(t_ref.dtype)


def ssd_chunk(xh, dt, A, Bm, Cm, *, chunk: int = 128,
              interpret: bool = False):
    """Intra-chunk SSD terms via Pallas.

    xh: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,);
    Bm, Cm: (B,S,N).  S must be a multiple of ``chunk``.
    Returns (y_intra (B,S,H,P), states (B,nc,H,N,P), T (B,nc,H)).
    """
    B, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = chunk
    assert S % Q == 0
    nc = S // Q

    # layout: (B, nc, H, Q, ...) so each grid cell is one (b, c, h)
    x_l = jnp.moveaxis(xh.reshape(B, nc, Q, H, Pd), 3, 2) \
        .reshape(B * nc * H, Q, Pd)
    dt_l = jnp.moveaxis(dt.reshape(B, nc, Q, H), 3, 2) \
        .reshape(B * nc * H, Q)
    b_l = jnp.broadcast_to(Bm.reshape(B, nc, 1, Q, N),
                           (B, nc, H, Q, N)).reshape(B * nc * H, Q, N)
    c_l = jnp.broadcast_to(Cm.reshape(B, nc, 1, Q, N),
                           (B, nc, H, Q, N)).reshape(B * nc * H, Q, N)
    a_l = jnp.broadcast_to(A[None, None, :],
                           (B, nc, H)).reshape(B * nc * H)

    y, s, t = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(B * nc * H,),
        in_specs=[
            pl.BlockSpec((1, Q, Pd), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q), lambda g: (g, 0)),
            pl.BlockSpec((1,), lambda g: (g,)),
            pl.BlockSpec((1, Q, N), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda g: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, Pd), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, N, Pd), lambda g: (g, 0, 0)),
            pl.BlockSpec((1,), lambda g: (g,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * nc * H, Q, Pd), jnp.float32),
            jax.ShapeDtypeStruct((B * nc * H, N, Pd), jnp.float32),
            jax.ShapeDtypeStruct((B * nc * H,), jnp.float32),
        ],
        interpret=interpret,
    )(x_l, dt_l, a_l, b_l, c_l)

    y = jnp.moveaxis(y.reshape(B, nc, H, Q, Pd), 2, 3).reshape(B, S, H, Pd)
    s = s.reshape(B, nc, H, N, Pd)
    t = t.reshape(B, nc, H)
    return y, s, t


def _ssd_forward(xh, dt, A, Bm, Cm, D, *, chunk: int = 128,
                 interpret: bool = False):
    """Full SSD output: Pallas intra-chunk terms + XLA cross-chunk scan.
    Mirrors models.mamba2.ssd_chunked (the oracle path)."""
    B, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S += pad
    nc = S // Q
    y_intra, states, T = ssd_chunk(xh, dt, A, Bm, Cm, chunk=Q,
                                   interpret=interpret)

    def body(h, xs):
        s_c, t_c = xs
        h_prev = h
        # states from the kernel are (N,P); carried state is (H,N,P)
        h = h * jnp.exp(t_c)[:, :, None, None] + s_c
        return h, h_prev

    h0 = jnp.zeros((B, H, N, Pd), jnp.float32)
    h_fin, h_prevs = jax.lax.scan(
        body, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(T, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                 # (B,nc,H,N,P)

    cum = jnp.cumsum(dt.astype(jnp.float32).reshape(B, nc, Q, H)
                     * A, axis=2)
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp",
                         Cm.astype(jnp.float32).reshape(B, nc, Q, N),
                         h_prevs, jnp.exp(cum))
    y = y_intra.reshape(B, nc, Q, H, Pd) + y_inter
    y = y.reshape(B, S, H, Pd) \
        + D[None, None, :, None] * xh.astype(jnp.float32)
    return y[:, :S_orig].astype(xh.dtype), h_fin.swapaxes(-1, -2)


@functools.lru_cache(maxsize=None)
def _ssd_with_vjp(chunk: int, interpret: bool):
    """custom_vjp SSD: Pallas forward, XLA-recompute backward.

    The backward re-runs ``models.mamba2.ssd_chunked`` (the XLA oracle
    path, whose reverse ``lax.scan`` IS the state-gradient scan) under
    ``jax.vjp`` and pulls the cotangents through it — so the gradient
    through the Pallas backend is bitwise-equal to the XLA backend's,
    at the cost of one forward recompute (the standard flash-style
    trade: recompute beats materializing per-chunk residuals in HBM).
    """

    @jax.custom_vjp
    def ssd(xh, dt, A, Bm, Cm, D):
        return _ssd_forward(xh, dt, A, Bm, Cm, D, chunk=chunk,
                            interpret=interpret)

    def fwd(xh, dt, A, Bm, Cm, D):
        out = _ssd_forward(xh, dt, A, Bm, Cm, D, chunk=chunk,
                           interpret=interpret)
        return out, (xh, dt, A, Bm, Cm, D)

    def bwd(res, cts):
        from repro.models.mamba2 import ssd_chunked  # avoid import cycle
        _, pull = jax.vjp(
            lambda *a: ssd_chunked(*a, chunk=chunk), *res)
        return pull(cts)

    ssd.defvjp(fwd, bwd)
    return ssd


def ssd_full(xh, dt, A, Bm, Cm, D, *, chunk: int = 128,
             interpret: bool = False):
    """Differentiable full SSD (see ``_ssd_with_vjp``).  Same contract
    as ``models.mamba2.ssd_chunked`` with ``h0=None``:
    returns (y (B,S,H,P), h_final (B,H,P,N))."""
    return _ssd_with_vjp(int(chunk), bool(interpret))(
        xh, dt, A, Bm, Cm, D)
