"""Continuous-batching serving engine over the paged KV pool.

The trainer's discipline — one executable per distinct batch shape,
exact token accounting — applied to serving under dynamic arrival:

- **Request-oriented API.**  Callers ``submit()`` a ``GenerationRequest``
  and either pump ``step()`` themselves (streaming: each step returns
  (rid, token, finished) events the moment they are sampled) or call
  ``drain()`` for the finished ``GenerationResult``s.  ``generate()`` is
  the synchronous compatibility wrapper matching the old blocking
  ``Server.generate`` signature.

- **Separate prefill and decode executables.**  Prefill runs one request
  at a time through the bucketed ragged prefill (prompts right-padded to
  a small ladder of bucket lengths), fused with the page scatter and
  greedy first-token sample into one executable per bucket.  Decode runs
  every slot — active or not — through ONE fixed-shape executable (the
  engine uses a single fixed slot count).  The compile-cache invariant
  is therefore ``executables <= #prompt-buckets + 1``, asserted by tests
  and by ``bench_serve --check-compiles``.

- **Admit/evict at every decode step.**  Pending requests are admitted
  into free slots whenever the pool can cover their worst-case page
  demand (a conservative reservation: admitted requests can never
  deadlock mid-decode); finished requests (EOS or max-tokens) are
  evicted and their pages freed the step they finish.  Pages are
  allocated lazily — a slot grows its page list only when its length
  crosses a page boundary — so eviction returns exactly what admission
  + growth took.

- **Greedy decoding**, pinned bitwise against the dense ``Server``
  oracle: one solo dense run per request must produce the same token
  ids the engine produced under any admit/evict interleaving (see
  tests/test_serving.py).

Both cache layouts of ``serving.cache`` are served: full-attention
transformer families run token-granular page tables
(``serving_mode == "paged"``); recurrent families (SSM) hold one
fixed-size state page per request (``serving_mode == "state"``) behind
the same admission/eviction machinery — their prefill is exact-length
(padding would pollute the recurrent state), so the compile budget there
is one executable per distinct prompt length instead of per bucket.

Inactive slots run the same executable with an all-null page-table row:
their writes land in the null page, their outputs are discarded, and —
proven by the oracle tests — they cannot leak into live requests.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import (Callable, Dict, List, Optional, Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry as R
from repro.serving import cache as SC


def pow2_buckets(max_prompt_len: int, min_bucket: int = 16) -> Tuple[int, ...]:
    """Power-of-two bucket ladder covering 1..max_prompt_len."""
    out, b = [], min_bucket
    while b < max_prompt_len:
        out.append(b)
        b *= 2
    out.append(max(max_prompt_len, min_bucket))
    return tuple(dict.fromkeys(out))


@dataclass
class GenerationRequest:
    """One generation job.  ``rid`` is assigned by ``submit()`` when
    omitted; pass one explicitly to correlate with an external queue."""

    prompt: np.ndarray                  # (S,) int32 token ids
    max_new_tokens: int
    eos_id: Optional[int] = None
    rid: Optional[int] = None


@dataclass
class GenerationResult:
    """A finished request: generated ids, the reason decoding stopped
    (``"eos"`` or ``"length"``), and — when the engine was built with a
    ``detokenizer`` — the decoded text."""

    rid: int
    tokens: np.ndarray                  # (n,) int32 generated ids
    finish_reason: str
    prompt_len: int
    text: Optional[str] = None


@dataclass
class _Slot:
    req: GenerationRequest
    length: int                         # tokens currently in the cache
    pages: List[int]
    total_pages: int                    # worst-case demand (reservation)
    out: List[int] = field(default_factory=list)
    last_token: int = 0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, decode_slots: int = 4,
                 page_size: int = 16, max_len: int = 256,
                 n_pages: Optional[int] = None,
                 buckets: Optional[Sequence[int]] = None,
                 dtype=jnp.bfloat16, prefill_chunk: int = 1024,
                 decode_chunk: int = 4096,
                 detokenizer: Optional[Callable[[Sequence[int]], str]]
                 = None):
        self.mode = R.serving_mode(cfg)
        if self.mode is None:
            raise NotImplementedError(
                f"continuous batching needs a paged or single-page cache; "
                f"arch_type={cfg.arch_type!r} with sliding_window="
                f"{cfg.sliding_window} serves via the dense train.serve."
                f"Server instead")
        self.cfg = cfg
        self.params = params
        self.dtype = dtype
        self.page_size = page_size if self.mode == "paged" else 1
        self.max_len = max_len                    # prompt + generated cap
        self.decode_slots = decode_slots
        self.detokenizer = detokenizer
        if self.mode == "paged":
            self.pages_per_slot = -(-max_len // self.page_size)
        else:
            self.pages_per_slot = 1               # O(1) recurrent state
        if n_pages is None:
            n_pages = decode_slots * self.pages_per_slot + 1
        self.pool = SC.PagePool(
            cfg, n_pages, self.page_size, dtype=dtype,
            kind="attn" if self.mode == "paged" else "state")
        self.buckets = tuple(sorted(buckets)) if buckets else \
            pow2_buckets(max_len)
        if self.buckets[-1] > self.pages_per_slot * self.page_size \
                and self.mode == "paged":
            raise ValueError(
                f"largest bucket {self.buckets[-1]} exceeds the per-slot "
                f"page window {self.pages_per_slot * self.page_size}")
        self._prefill_chunk = prefill_chunk
        self._decode_chunk = decode_chunk
        self._prefill_fns: Dict[int, callable] = {}     # bucket -> jit
        self._decode_fns: Dict[int, callable] = {}      # batch -> jit
        self.slots: List[Optional[_Slot]] = [None] * decode_slots
        self._pending: deque = deque()
        self._completed: List[GenerationResult] = []
        self._results: Dict[int, GenerationResult] = {}
        self._live_rids: set = set()
        self._next_rid = 0
        self._reserved = 0              # future pages owed to active slots
        self.steps = 0
        self._occupancy_sum = 0.0

    # ----------------------------------------------------------------- #
    # compile-cache bookkeeping
    # ----------------------------------------------------------------- #

    @property
    def n_prefill_executables(self) -> int:
        return len(self._prefill_fns)

    @property
    def n_decode_executables(self) -> int:
        return len(self._decode_fns)

    @property
    def executables(self) -> int:
        return self.n_prefill_executables + self.n_decode_executables

    @property
    def executable_budget(self) -> int:
        """The serving compile invariant: one prefill executable per
        prompt bucket (``"paged"``; per distinct prompt length for
        ``"state"``, whose exact-length prefill cannot be padded) plus
        one decode executable per decode batch size (this engine runs a
        single fixed slot count)."""
        if self.mode == "paged":
            return len(self.buckets) + 1
        return len(self._prefill_fns) + 1

    def _bucket_for(self, s: int) -> int:
        for b in self.buckets:
            if s <= b:
                return b
        raise ValueError(f"prompt length {s} exceeds largest bucket "
                         f"{self.buckets[-1]}")

    def _prefill_fn(self, key: int):
        fn = self._prefill_fns.get(key)
        if fn is None:
            impl = (_prefill_impl if self.mode == "paged"
                    else _state_prefill_impl)
            fn = jax.jit(partial(
                impl, cfg=self.cfg, page_size=self.page_size,
                dtype=self.dtype, attn_chunk=self._prefill_chunk))
            self._prefill_fns[key] = fn
        return fn

    def _decode_fn(self, batch: int):
        fn = self._decode_fns.get(batch)
        if fn is None:
            fn = jax.jit(partial(
                _decode_impl, cfg=self.cfg, page_size=self.page_size,
                kind=self.pool.kind, dtype=self.dtype,
                attn_chunk=self._decode_chunk))
            self._decode_fns[batch] = fn
        return fn

    # ----------------------------------------------------------------- #
    # request lifecycle
    # ----------------------------------------------------------------- #

    def submit(self, req: GenerationRequest) -> int:
        """Queue a request; returns its rid.  Admission into a decode
        slot happens inside ``step()`` once the page pool can cover the
        request's worst-case demand."""
        s = int(np.asarray(req.prompt).shape[0])
        if s < 1 or req.max_new_tokens < 1:
            raise ValueError("prompt and max_new_tokens must be "
                             "non-empty")
        if s + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request: prompt {s} + max_new_tokens "
                f"{req.max_new_tokens} exceeds max_len {self.max_len}")
        if self.mode == "paged":
            self._bucket_for(s)         # fail fast on oversized prompts
        if req.rid is None:
            req.rid = self._next_rid
            self._next_rid += 1
        else:
            self._next_rid = max(self._next_rid, req.rid + 1)
        if req.rid in self._live_rids:
            raise ValueError(f"rid {req.rid} is already queued or active")
        self._live_rids.add(req.rid)
        self._pending.append(req)
        return req.rid

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    @property
    def done(self) -> bool:
        return not self._pending and self.n_active == 0

    def _admit(self, events) -> None:
        """Admit head-of-line pending requests into free slots while the
        pool can cover their worst-case page demand."""
        for i, slot in enumerate(self.slots):
            if slot is not None or not self._pending:
                continue
            req = self._pending[0]
            S = len(req.prompt)
            # the last sampled token is never written back, so the
            # worst case stores S + max_new_tokens - 1 positions
            # (recurrent state is O(1): always exactly one page)
            total = self._pages_needed(S + req.max_new_tokens - 1)
            if self.pool.n_free - self._reserved < total:
                break                   # head-of-line blocking, FIFO order
            self._pending.popleft()
            pages = self.pool.alloc(self._pages_needed(S))
            self._reserved += total - len(pages)
            slot = _Slot(req=req, length=0, pages=pages, total_pages=total)
            self.slots[i] = slot
            tok = self._run_prefill(slot)
            slot.length = S
            self._emit(i, slot, tok, events)

    def _run_prefill(self, slot: _Slot) -> int:
        S = len(slot.req.prompt)
        row = np.full((1, self.pages_per_slot), SC.NULL_PAGE, np.int32)
        row[0, :len(slot.pages)] = slot.pages
        if self.mode == "paged":
            bucket = self._bucket_for(S)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :S] = slot.req.prompt
            fn = self._prefill_fn(bucket)
        else:
            # exact-length prefill: right-padding would run the
            # recurrent scan over padding tokens and corrupt the state
            toks = np.asarray(slot.req.prompt, np.int32)[None]
            fn = self._prefill_fn(S)
        tok, self.pool.kv = fn(self.params, self.pool.kv,
                               jnp.asarray(toks),
                               jnp.asarray([S], jnp.int32),
                               jnp.asarray(row))
        return int(np.asarray(tok)[0, 0])

    def _emit(self, i: int, slot: _Slot, tok: int, events) -> None:
        slot.out.append(tok)
        slot.last_token = tok
        eos = (slot.req.eos_id is not None and tok == slot.req.eos_id)
        done = eos or len(slot.out) >= slot.req.max_new_tokens
        events.append((slot.req.rid, tok, done))
        if done:
            self._finish(i, "eos" if eos else "length")

    def _finish(self, i: int, reason: str) -> None:
        slot = self.slots[i]
        self.pool.free(slot.pages)
        self._reserved -= slot.total_pages - len(slot.pages)
        toks = np.asarray(slot.out, np.int32)
        res = GenerationResult(
            rid=slot.req.rid, tokens=toks, finish_reason=reason,
            prompt_len=len(slot.req.prompt),
            text=(self.detokenizer(toks.tolist())
                  if self.detokenizer else None))
        self._completed.append(res)
        self._results[slot.req.rid] = res
        self._live_rids.discard(slot.req.rid)
        self.slots[i] = None

    def _pages_needed(self, n_tokens: int) -> int:
        """Worst-case pages for ``n_tokens``: token-granular for the
        paged mode, exactly one fixed-size state page for recurrent."""
        if self.mode == "state":
            return 1
        return self.pool.pages_for(n_tokens)

    def _grow_pages(self) -> None:
        """Lazy allocation: a slot gets its next page only when the next
        write would cross into it (covered by the admit reservation).
        State slots never grow — their page holds O(1) state."""
        if self.mode == "state":
            return
        for slot in self.slots:
            if slot is None:
                continue
            if slot.length >= len(slot.pages) * self.page_size:
                slot.pages.extend(self.pool.alloc(1))
                self._reserved -= 1

    # ----------------------------------------------------------------- #
    # the step loop
    # ----------------------------------------------------------------- #

    def step(self) -> List[Tuple[int, int, bool]]:
        """One engine step: admit + prefill new requests, then one decode
        step over every slot.  Returns (rid, token, finished) streaming
        events in emission order."""
        events: List[Tuple[int, int, bool]] = []
        self._admit(events)
        active = [(i, s) for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return events
        self._grow_pages()
        B = self.decode_slots
        pages = np.full((B, self.pages_per_slot), SC.NULL_PAGE, np.int32)
        lengths = np.zeros((B,), np.int32)
        toks = np.zeros((B, 1), np.int32)
        for i, slot in active:
            pages[i, :len(slot.pages)] = slot.pages
            lengths[i] = slot.length
            toks[i, 0] = slot.last_token
        fn = self._decode_fn(B)
        nxt, self.pool.kv = fn(self.params, self.pool.kv,
                               jnp.asarray(pages), jnp.asarray(lengths),
                               jnp.asarray(toks))
        nxt = np.asarray(nxt)
        for i, slot in active:
            slot.length += 1
            self._emit(i, slot, int(nxt[i, 0]), events)
        self.steps += 1
        self._occupancy_sum += len(active) / self.decode_slots
        return events

    def drain(self, max_steps: Optional[int] = None) \
            -> List[GenerationResult]:
        """Step until every queued request finishes; returns the results
        completed since the last drain, in completion order."""
        n = 0
        while not self.done:
            self.step()
            n += 1
            if max_steps is not None and n >= max_steps:
                raise RuntimeError(f"engine not drained after {n} steps")
        out, self._completed = self._completed, []
        return out

    def result(self, rid: int) -> Optional[GenerationResult]:
        return self._results.get(rid)

    def generate(self, tokens: np.ndarray, n_new: int, *,
                 eos_id: Optional[int] = None) -> np.ndarray:
        """Synchronous compatibility wrapper over submit/drain matching
        the blocking ``Server.generate`` contract: tokens (B, S) prompt
        rows, returns (B, n_new) greedy ids (rows that hit ``eos_id``
        early are zero-padded)."""
        tokens = np.asarray(tokens)
        rids = [self.submit(GenerationRequest(
            prompt=tokens[b].astype(np.int32), max_new_tokens=n_new,
            eos_id=eos_id)) for b in range(tokens.shape[0])]
        self.drain()
        out = np.zeros((tokens.shape[0], n_new), np.int32)
        for b, rid in enumerate(rids):
            got = self._results[rid].tokens
            out[b, :len(got)] = got
        return out

    # ----------------------------------------------------------------- #
    # maintenance
    # ----------------------------------------------------------------- #

    def defrag(self) -> None:
        """Compact live pages to the low pool ids (one device gather);
        active slots' page tables are rewritten in place."""
        self.pool.defrag([s.pages for s in self.slots if s is not None])

    def reset(self) -> None:
        """Drop all requests and free every page; compiled executables
        are kept (the compile cache is the expensive part)."""
        for i, slot in enumerate(self.slots):
            if slot is not None:
                self._finish(i, "reset")
        self._pending.clear()
        self._completed.clear()
        self._results.clear()
        self._live_rids.clear()
        self.steps = 0
        self._occupancy_sum = 0.0
        assert self._reserved == 0 and self.pool.n_used == 0

    def mean_occupancy(self) -> float:
        return self._occupancy_sum / max(self.steps, 1)


# --------------------------------------------------------------------- #
# jitted bodies (module-level so partials stay hashable/stable)
# --------------------------------------------------------------------- #

def _greedy(logits):
    return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)


def _prefill_impl(params, pool_kv, tokens, lengths, pages_row, *, cfg,
                  page_size, dtype, attn_chunk):
    """Prefill one request (B=1), scatter its K/V into its pages, and
    greedy-sample the first token — fused into one executable per
    prompt bucket."""
    logits, k, v = R.prefill_ragged(params, cfg, tokens, lengths,
                                    dtype=dtype, attn_chunk=attn_chunk)
    pool_kv = SC.scatter_prefill(pool_kv, k, v, pages_row, lengths,
                                 page_size=page_size)
    return _greedy(logits), pool_kv


def _state_prefill_impl(params, pool_kv, tokens, lengths, pages_row, *,
                        cfg, page_size, dtype, attn_chunk):
    """Exact-length prefill for a recurrent family: run the family
    prefill and scatter the resulting state into the request's pool
    row."""
    del lengths, page_size, attn_chunk          # exact length, O(1) state
    logits, cache = R.prefill(params, cfg, tokens, dtype=dtype)
    pool_kv = SC.scatter_state(pool_kv, cache.data, pages_row[:, 0])
    return _greedy(logits), pool_kv


def _decode_impl(params, pool_kv, pages, lengths, token, *, cfg,
                 page_size, kind, dtype, attn_chunk):
    """One fixed-shape decode step over every slot + greedy sampling —
    the redesigned ``registry.decode_step`` with a ``PagedKVCache``."""
    cache = SC.PagedKVCache(kv=pool_kv, pages=pages, lengths=lengths,
                            page_size=page_size, kind=kind)
    logits, new_cache = R.decode_step(params, cfg, cache, token,
                                      dtype=dtype, attn_chunk=attn_chunk)
    return _greedy(logits), new_cache.kv
