"""Serving subsystem: typed KV caches, the paged pool, and the
continuous-batching engine.

``serving.cache`` owns the cache layouts (the ``KVCache`` protocol with
its dense and paged implementations, the page pool and its device
plumbing); ``serving.engine`` owns the request lifecycle
(``GenerationRequest`` -> submit/step/drain -> ``GenerationResult``).
The dense blocking ``Server`` in ``train.serve`` remains as the oracle
and the fallback for families without a paged/state serving mode.
"""
from repro.serving.cache import (NULL_PAGE, DenseKVCache, KVCache,
                                 OutOfPages, PagedKVCache, PagePool)
from repro.serving.engine import (GenerationRequest, GenerationResult,
                                  ServingEngine, pow2_buckets)

__all__ = [
    "KVCache", "DenseKVCache", "PagedKVCache", "PagePool", "OutOfPages",
    "NULL_PAGE", "ServingEngine", "GenerationRequest", "GenerationResult",
    "pow2_buckets",
]
