"""Typed KV caches for serving: one protocol, two layouts.

``KVCache`` is the contract ``registry.prefill`` / ``registry.decode_step``
speak: a pytree that carries its own per-request ``lengths`` (B,) int32,
so callers never thread a scalar ``cache_len`` beside the cache again.

``DenseKVCache`` wraps the contiguous per-family cache pytree the models
have always built (transformer K/V, ring buffers, recurrent state,
enc-dec cross K/V) — the training/eval layout, one row per request.

``PagedKVCache`` is the serving layout: requests own fixed-size pages of
a preallocated pool and carry per-request page tables, so admission,
eviction, and ragged depths never retrigger compilation.  It serves two
families of state:

- ``kind="attn"`` — the fused head-interleaved KV pool of the
  tpu_commons/sglang-jax lineage, one buffer per model:

      kv: (L, n_pages, page_size, 2 * n_kv_heads, head_dim)

  where head h's K lives at interleaved index 2h and its V at 2h + 1 —
  ``[K0, V0, K1, V1, ...]`` — so a page gather lands K and V for a head
  adjacent in memory and one lookup feeds both operands of attention.

- ``kind="state"`` — recurrent families (SSM) hold O(1) state, so each
  request is exactly one page (``page_size == 1``) of a state pool whose
  leaves put the page id on axis 1: ``(L, n_pages, ...)``.  The same
  admission/eviction machinery serves both kinds.

Page id 0 is the NULL page: the allocator never hands it out, and every
write addressed by an inactive decode slot (or a masked prefill row) is
routed there, so inactive lanes run the same executable as active ones
without a scatter-guard.  Stale data in the null page — or in any reused
page beyond a request's length — is unreachable: the ragged attention
masks every position beyond the causal reach
(``kernels.backend.paged_decode_attention``).

Host-side bookkeeping is ``PagePool``: a free list, alloc/free, and a
``defrag`` that compacts live pages to the low ids with a single device
gather (permutation) and rewrites the page tables in place.  Device-side
plumbing is pure-functional and jit-composable: ``scatter_prefill``
writes a prompt's K/V into its pages, ``paged_decode`` runs one decode
step against a ``PagedKVCache`` — the serving counterpart of the dense
``decode_step``, with the scalar cache length promoted to per-request
``lengths`` so one executable serves slots at ragged depths.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro.configs.base import ModelConfig
from repro.kernels import backend as KB
from repro.models import moe as M
from repro.models.layers import apply_rope, mlp, rmsnorm
from repro.models.transformer import logits_from_hidden

Params = Dict[str, Any]

NULL_PAGE = 0


class OutOfPages(RuntimeError):
    """The pool has no free page for a required allocation."""


# --------------------------------------------------------------------- #
# the cache protocol and its two implementations
# --------------------------------------------------------------------- #

@runtime_checkable
class KVCache(Protocol):
    """What ``registry.decode_step`` needs from a cache: per-request
    valid lengths.  Both implementations are registered pytrees, so they
    pass through jit/eval_shape/tree.map untouched."""

    lengths: jax.Array          # (B,) int32 — tokens cached per request


@dataclasses.dataclass
class DenseKVCache:
    """The contiguous per-family cache: ``data`` is whatever pytree the
    family's ``prefill`` builds (row b of every leaf belongs to request
    b).  Full-attention transformer caches step at per-request depths;
    uniform layouts (ring windows, recurrent state, enc-dec) keep all
    rows at ``lengths[0]``."""

    data: Any
    lengths: jax.Array


@dataclasses.dataclass
class PagedKVCache:
    """The pooled serving cache.  ``kv`` is the shared pool buffer (the
    fused attn array, or the state pytree for ``kind="state"``);
    ``pages`` (B, P) int32 are per-request page tables (unused slots
    hold ``NULL_PAGE``); ``page_size``/``kind`` are static so they key
    the executable, not feed it."""

    kv: Any
    pages: jax.Array
    lengths: jax.Array
    page_size: int = 16
    kind: str = "attn"


jtu.register_dataclass(DenseKVCache, data_fields=["data", "lengths"],
                       meta_fields=[])
jtu.register_dataclass(PagedKVCache,
                       data_fields=["kv", "pages", "lengths"],
                       meta_fields=["page_size", "kind"])


# --------------------------------------------------------------------- #
# host-side page allocator
# --------------------------------------------------------------------- #

class PagePool:
    """Preallocated paged pool + host-side page allocator.

    ``capacity`` usable pages (page 0 is reserved as the null page).
    The device buffer ``kv`` is replaced functionally by the jitted
    scatter/decode executables; the host side only tracks which page ids
    are free."""

    def __init__(self, cfg: ModelConfig, n_pages: int, page_size: int,
                 dtype=jnp.bfloat16, kind: str = "attn"):
        if n_pages < 2:
            raise ValueError("PagePool needs >= 2 pages (page 0 is the "
                             "reserved null page)")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if kind not in ("attn", "state"):
            raise ValueError(f"unknown pool kind {kind!r}")
        if kind == "state" and page_size != 1:
            raise ValueError("state pools hold one fixed-size state per "
                             "page; page_size must be 1")
        self.cfg = cfg
        self.page_size = page_size
        self.n_pages = n_pages
        self.dtype = dtype
        self.kind = kind
        self.kv = self._fresh_buffer()
        # LIFO free list: freshly freed (hot) pages are reused first
        self._free: List[int] = list(range(n_pages - 1, 0, -1))

    def _fresh_buffer(self):
        if self.kind == "attn":
            return jnp.zeros(
                (self.cfg.n_layers, self.n_pages, self.page_size,
                 2 * self.cfg.n_kv_heads, self.cfg.head_dim), self.dtype)
        from repro.models import registry as R  # deferred: import cycle
        # state leaves carry the page id on axis 1: (L, n_pages, ...)
        return R.cache_struct(self.cfg, self.n_pages, 1, self.dtype)

    def cache(self, pages, lengths) -> PagedKVCache:
        """View the pool + a batch's tables/lengths as a PagedKVCache."""
        return PagedKVCache(kv=self.kv, pages=pages, lengths=lengths,
                            page_size=self.page_size, kind=self.kind)

    @property
    def capacity(self) -> int:
        return self.n_pages - 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.capacity - self.n_free

    def occupancy(self) -> float:
        return self.n_used / self.capacity

    def pages_for(self, n_tokens: int) -> int:
        return max(-(-n_tokens // self.page_size), 1)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise OutOfPages(
                f"need {n} pages, {len(self._free)} free "
                f"(capacity {self.capacity})")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if not 0 < p < self.n_pages:
                raise ValueError(f"free of invalid page id {p}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
        self._free.extend(pages)

    def reset(self) -> None:
        """Free everything and zero the buffer (fresh pool, same
        executables — shapes are unchanged)."""
        self.kv = self._fresh_buffer()
        self._free = list(range(self.n_pages - 1, 0, -1))

    def defrag(self, tables: Sequence[List[int]]) -> None:
        """Compact every live page to the lowest ids: one device gather
        permutes the pool, and each table in ``tables`` (mutable lists of
        page ids, e.g. the engine's per-slot lists) is rewritten in
        place.  Pages not covered by any table are treated as free."""
        live = [p for table in tables for p in table]
        if len(set(live)) != len(live):
            raise ValueError("defrag: a page id appears in two tables")
        remap = {old: new for new, old in enumerate(live, start=1)}
        src = list(range(self.n_pages))          # new id -> old id
        for old, new in remap.items():
            src[new] = old
        perm = jnp.asarray(src, jnp.int32)
        self.kv = jax.tree.map(lambda a: jnp.take(a, perm, axis=1),
                               self.kv)
        for table in tables:
            table[:] = [remap[p] for p in table]
        self._free = list(range(self.n_pages - 1, len(live), -1))


# --------------------------------------------------------------------- #
# device-side layout plumbing (pure, jit-composable)
# --------------------------------------------------------------------- #

def kv_interleave(k, v):
    """k, v: (..., Hkv, hd) -> (..., 2*Hkv, hd) as [K0, V0, K1, V1, ...]."""
    Hkv, hd = k.shape[-2], k.shape[-1]
    return jnp.stack([k, v], axis=-2).reshape(*k.shape[:-2], 2 * Hkv, hd)


def kv_deinterleave(kv):
    """(..., 2*Hkv, hd) -> (k, v) each (..., Hkv, hd)."""
    return kv[..., 0::2, :], kv[..., 1::2, :]


def gather_pages(pool_layer, pages, *, page_size: int):
    """pool_layer: (n_pages, page_size, 2*Hkv, hd); pages: (B, P) int32.
    Returns (k, v) each (B, P * page_size, Hkv, hd) — slot s holds
    absolute position s of its request (junk beyond the request's length
    is masked downstream by the causal reach)."""
    B, P = pages.shape
    kv = pool_layer[pages]                       # (B, P, ps, 2Hkv, hd)
    kv = kv.reshape(B, P * page_size, *kv.shape[3:])
    return kv_deinterleave(kv)


def scatter_prefill(pool_kv, k, v, pages, lengths, *, page_size: int):
    """Write prompt K/V into the pool.  pool_kv: (L, n_pages, ps, 2Hkv,
    hd); k, v: (L, B, S, Hkv, hd) from ``prefill_ragged``; pages: (B, P)
    page-table rows (P * ps >= S); lengths: (B,) true prompt lengths —
    rows at positions >= lengths[b] (bucket padding) go to the null
    page."""
    L, B, S, Hkv, hd = k.shape
    t = jnp.arange(S)
    page_of_t = jnp.where(t[None, :] < lengths[:, None],
                          pages[:, t // page_size], NULL_PAGE)   # (B, S)
    offs = jnp.broadcast_to((t % page_size)[None, :], (B, S))
    kv = kv_interleave(k, v).astype(
        jax.tree.leaves(pool_kv)[0].dtype)       # (L, B, S, 2Hkv, hd)
    return pool_kv.at[:, page_of_t, offs].set(kv)


def scatter_state(pool_kv, state, rows):
    """Write per-request recurrent state into its pool rows.  pool_kv
    leaves: (L, n_pages, ...); state leaves: (L, B, ...); rows: (B,)
    page ids (one page per request for ``kind="state"``)."""
    return jax.tree.map(
        lambda p, s: p.at[:, rows].set(s.astype(p.dtype)), pool_kv, state)


def gather_state(pool_kv, rows):
    """Per-request state rows out of the pool: inverse of
    ``scatter_state`` (leaves (L, n_pages, ...) -> (L, B, ...))."""
    return jax.tree.map(lambda p: p[:, rows], pool_kv)


# --------------------------------------------------------------------- #
# paged decode forward
# --------------------------------------------------------------------- #

def paged_decode_attn(params: Params, x, pool_layer, pages, lengths, *,
                      page_size: int, n_heads: int, n_kv_heads: int,
                      head_dim: int, rope_theta: float,
                      backend: str = "xla", chunk: int = 4096):
    """One layer of paged decode attention.  x: (B, 1, d); pool_layer:
    (n_pages, ps, 2Hkv, hd); pages: (B, P); lengths: (B,) tokens already
    cached per slot (= the new token's absolute position).  Inactive
    slots carry all-null page-table rows, so their writes land in the
    null page and their (garbage) outputs are discarded by the host.
    Returns (out (B, 1, d), new_pool_layer)."""
    B = x.shape[0]
    q = (x @ params["w_q"].astype(x.dtype)).reshape(B, 1, n_heads,
                                                    head_dim)
    k = (x @ params["w_k"].astype(x.dtype)).reshape(B, 1, n_kv_heads,
                                                    head_dim)
    v = (x @ params["w_v"].astype(x.dtype)).reshape(B, 1, n_kv_heads,
                                                    head_dim)
    if rope_theta:
        ppos = lengths[:, None]                      # (B, 1) per-request
        q = apply_rope(q, ppos, rope_theta)
        k = apply_rope(k, ppos, rope_theta)

    # scatter the new token: position `lengths[b]` lives in page
    # lengths[b] // ps at offset lengths[b] % ps of that slot's table
    kv_tok = kv_interleave(k[:, 0], v[:, 0]).astype(pool_layer.dtype)
    page = jnp.take_along_axis(pages, (lengths // page_size)[:, None],
                               axis=1)[:, 0]         # (B,)
    off = lengths % page_size
    new_pool = pool_layer.at[page, off].set(kv_tok)

    kk, vv = gather_pages(new_pool, pages, page_size=page_size)
    o = KB.paged_decode_attention(q, kk.astype(q.dtype),
                                  vv.astype(q.dtype), lengths,
                                  backend=backend, chunk=chunk)
    o = o.reshape(B, 1, n_heads * head_dim)
    return o @ params["w_o"].astype(x.dtype), new_pool


def _paged_attn_decode(params: Params, cfg: ModelConfig,
                       cache: PagedKVCache, token, *,
                       dtype=jnp.bfloat16, attn_chunk: int = 4096):
    """One decode step over the attention page pool — the paged
    counterpart of ``transformer.decode_step`` with per-request lengths.
    token: (B, 1) int32.  Returns (logits (B, 1, V), new cache)."""
    pages, lengths = cache.pages, cache.lengths
    emb = params["embed"]["tok"].astype(dtype)
    x = emb[token]

    def body(x, xs):
        pl, pool_layer = xs
        h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
        a, new_pool = paged_decode_attn(
            pl["attn"], h, pool_layer, pages, lengths,
            page_size=cache.page_size, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, backend=cfg.kernel_backend,
            chunk=attn_chunk)
        x = x + a
        h = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        if cfg.arch_type == "moe":
            f, _ = M.moe_forward(pl["moe"], h, cfg)
        else:
            f = mlp(pl["mlp"], h, cfg.act)
        return x + f, new_pool

    x, new_kv = jax.lax.scan(body, x, (params["layers"], cache.kv))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x)
    new_cache = dataclasses.replace(cache, kv=new_kv,
                                    lengths=lengths + 1)
    return logits, new_cache


def _paged_state_decode(params: Params, cfg: ModelConfig,
                        cache: PagedKVCache, token, *,
                        dtype=jnp.bfloat16, **kw):
    """One decode step for a recurrent family served from a state pool:
    gather each request's state row, run the family's position-free
    decode, scatter back.  Inactive slots point at the null row, whose
    garbage is never read by a live request (duplicate null writes
    last-write-win into row 0, which nobody owns)."""
    from repro.models import registry as R  # deferred: import cycle
    rows = cache.pages[:, 0]
    state = gather_state(cache.kv, rows)
    # recurrent decode ignores absolute position (the state IS the
    # history), so a shared scalar 0 is exact at ragged depths
    logits, new_state, _ = R.family(cfg).decode_step(
        params, cfg, state, jnp.int32(0), token, dtype=dtype, **kw)
    new_kv = scatter_state(cache.kv, new_state, rows)
    new_cache = dataclasses.replace(cache, kv=new_kv,
                                    lengths=cache.lengths + 1)
    return logits, new_cache


def paged_decode(params: Params, cfg: ModelConfig, cache: PagedKVCache,
                 token, *, dtype=jnp.bfloat16, attn_chunk: int = 4096,
                 **kw):
    """``registry.decode_step``'s paged branch: dispatch on the pool
    kind.  Returns (logits (B, 1, V), new PagedKVCache)."""
    if cache.kind == "attn":
        return _paged_attn_decode(params, cfg, cache, token, dtype=dtype,
                                  attn_chunk=attn_chunk)
    return _paged_state_decode(params, cfg, cache, token, dtype=dtype,
                               **kw)
