import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import moe as M

CFG = get_config("granite-moe-1b-a400m").reduced()


def _x(B=2, S=64, d=None):
    d = d or CFG.d_model
    return jax.random.normal(jax.random.PRNGKey(0), (B, S, d),
                             jnp.float32)


def test_output_shape_and_finite():
    params = M.init_moe(jax.random.PRNGKey(1), CFG)
    y, aux = M.moe_forward(params, _x(), CFG)
    assert y.shape == (2, 64, CFG.d_model)
    assert bool(jnp.isfinite(y).all())
    assert 0.0 <= float(aux["frac_dropped"]) < 1.0


def test_no_drop_at_high_capacity():
    cfg = dataclasses.replace(
        CFG, moe=dataclasses.replace(CFG.moe, capacity_factor=16.0))
    params = M.init_moe(jax.random.PRNGKey(1), cfg)
    _, aux = M.moe_forward(params, _x(), cfg)
    assert float(aux["frac_dropped"]) == 0.0


def test_load_balance_loss_lower_bound():
    """Switch LB loss ≥ 1 (equality at perfect balance)."""
    params = M.init_moe(jax.random.PRNGKey(1), CFG)
    _, aux = M.moe_forward(params, _x(B=4, S=128), CFG)
    assert float(aux["lb_loss"]) >= 0.99


def test_capacity_drops_increase_when_squeezed():
    tight = dataclasses.replace(
        CFG, moe=dataclasses.replace(CFG.moe, capacity_factor=0.25))
    params = M.init_moe(jax.random.PRNGKey(1), tight)
    _, aux = M.moe_forward(params, _x(), tight)
    assert float(aux["frac_dropped"]) > 0.0


def test_group_size_invariance_when_no_drops():
    cfg = dataclasses.replace(
        CFG, moe=dataclasses.replace(CFG.moe, capacity_factor=32.0))
    params = M.init_moe(jax.random.PRNGKey(1), cfg)
    x = _x(B=2, S=64)
    y1, _ = M.moe_forward(params, x, cfg, group_size=32)
    y2, _ = M.moe_forward(params, x, cfg, group_size=128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)


def test_gates_renormalized():
    """Top-k gate weights are renormalized: scaling router logits by a
    constant shifts nothing."""
    params = M.init_moe(jax.random.PRNGKey(1), CFG)
    y1, _ = M.moe_forward(params, _x(), CFG)
    assert bool(jnp.isfinite(y1).all())


def test_gradients_flow_to_experts_and_router():
    params = M.init_moe(jax.random.PRNGKey(1), CFG)
    x = _x()

    def loss(p):
        y, aux = M.moe_forward(p, x, CFG)
        return jnp.mean(jnp.square(y)) + 0.01 * aux["lb_loss"]

    g = jax.grad(loss)(params)
    assert float(jnp.max(jnp.abs(g["router"]))) > 0
    assert float(jnp.max(jnp.abs(g["w_up"]))) > 0
