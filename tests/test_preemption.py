"""Preemption handling and elastic-resume validation (host-only).

The launcher pieces that need no jax device world: the signal guard's
stop/grace bookkeeping, the coordinator-connect retry loop, and the
from-the-resume-point ramp validation that makes elastic resumes onto
a smaller/larger topology either work or fail with a clear error.
"""
import os
import signal

import pytest

from repro.core.seesaw import build_plan
from repro.launch.steps import validate_feeding
from repro.launch.train import (PreemptionGuard,
                                init_distributed_with_retry)

SEQ = 32


def _plan():
    # batch ramp 8 -> 16 -> 32
    return build_plan(kind="seesaw", base_lr=1e-3,
                      total_tokens=SEQ * 8 * 24, warmup_frac=0.0,
                      b0=8, alpha=2.0, n_cuts=2)


class TestRetry:
    def test_succeeds_after_transient_failures(self):
        calls, sleeps = [], []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise ConnectionError("coordinator not up yet")
            return "ok"

        out = init_distributed_with_retry(
            flaky, attempts=4, backoff=0.5, sleep=sleeps.append,
            log=lambda *a: None)
        assert out == "ok" and len(calls) == 3
        assert sleeps == [0.5, 1.0]        # exponential backoff

    def test_exhaustion_raises_last_error(self):
        sleeps = []

        def dead():
            raise ConnectionError("never")

        with pytest.raises(ConnectionError, match="never"):
            init_distributed_with_retry(
                dead, attempts=3, backoff=1.0, sleep=sleeps.append,
                log=lambda *a: None)
        assert sleeps == [1.0, 2.0]        # no sleep after last try


class TestPreemptionGuard:
    def test_sigterm_requests_stop_within_grace(self):
        g = PreemptionGuard(grace=30.0).install()
        try:
            assert not g.requested() and not g.should_stop()
            assert g.grace_remaining() == 30.0
            os.kill(os.getpid(), signal.SIGTERM)
            assert g.requested() and g.should_stop()
            assert 0.0 < g.grace_remaining() <= 30.0
        finally:
            g.uninstall()

    def test_uninstall_restores_previous_handler(self):
        seen = []
        prev = signal.signal(signal.SIGTERM,
                             lambda *a: seen.append("prev"))
        try:
            g = PreemptionGuard().install()
            g.uninstall()
            os.kill(os.getpid(), signal.SIGTERM)
            assert seen == ["prev"]
            assert not g.requested()
        finally:
            signal.signal(signal.SIGTERM, prev)


class TestElasticValidateFeeding:
    def test_whole_ramp_fails_on_too_many_processes(self):
        # phase 0's global batch 8 cannot split over 16 processes
        with pytest.raises(ValueError, match="phase 0.*16 host"):
            validate_feeding(_plan(), None, process_count=16)

    def test_resume_past_infeasible_phase_passes(self):
        """Elastic resume: 16 processes cannot feed phase 0 (batch 8),
        but a checkpoint already past the phase-0/1 boundary only needs
        phases 1+ (batch 16, 32) — validation from the resume point
        must pass."""
        plan = _plan()
        boundary = plan.steps_per_phase(SEQ)[0] * 8 * SEQ
        validate_feeding(plan, None, process_count=16,
                         start_tokens=boundary, seq_len=SEQ)

    def test_resume_before_boundary_still_fails(self):
        plan = _plan()
        inside0 = 2 * 8 * SEQ              # still in phase 0
        with pytest.raises(ValueError, match="phase 0.*16 host"):
            validate_feeding(plan, None, process_count=16,
                             start_tokens=inside0, seq_len=SEQ)

    def test_resume_cannot_feed_final_phase_names_resume_point(self):
        # 64 processes can never feed this ramp (max batch 32), even
        # from the last boundary — the error names the offending phase
        # AND the resume point
        plan = _plan()
        steps = plan.steps_per_phase(SEQ)
        last = (steps[0] * 8 + steps[1] * 16) * SEQ
        with pytest.raises(ValueError,
                           match="phase 2.*64 host.*resuming at "
                                 "phase 2"):
            validate_feeding(plan, None, process_count=64,
                             start_tokens=last, seq_len=SEQ)
