"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned family — one forward/train step on CPU, asserting output shapes
and no NaNs — plus prefill→decode consistency with the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import registry as R
from repro.models.transformer import logits_from_hidden
from repro.optim import optimizers as O

REDUCED = {name: get_config(name).reduced() for name in ASSIGNED_ARCHS}


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_reduced_config_limits(name):
    cfg = REDUCED[name]
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_forward_and_train_step(name):
    cfg = REDUCED[name]
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    batch = R.concrete_inputs(cfg, "train", 2, 64)

    def loss_of(p):
        return R.loss_fn(p, cfg, batch, remat=True)

    (loss, metrics), grads = jax.value_and_grad(
        loss_of, has_aux=True)(params)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name
    # one optimizer step moves params and keeps them finite
    opt = O.adamw()
    st = opt.init(params)
    new_params, _ = opt.update(grads, st, params, 1e-3)
    leaves = jax.tree.leaves(new_params)
    assert all(bool(jnp.isfinite(x).all()) for x in leaves)
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), leaves))
    assert moved


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_loss_near_uniform_at_init(name):
    cfg = REDUCED[name]
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    batch = R.concrete_inputs(cfg, "train", 2, 64)
    loss, _ = R.loss_fn(params, cfg, batch, remat=False)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.0


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_prefill_decode_matches_forward(name):
    cfg = REDUCED[name]
    if cfg.arch_type == "moe":   # exactness needs no-drop capacity
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = R.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    S = 33
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                    (2, S + 1)).astype(np.int32))
    prefix = None
    if cfg.arch_type in ("vlm", "audio", "encdec"):
        prefix = jnp.asarray(rng.normal(0, 1, (2, cfg.frontend_tokens,
                                               cfg.frontend_dim)),
                             jnp.float32)
    h, _ = R.forward_hidden(params, cfg, toks, prefix_emb=prefix,
                            remat=False, dtype=jnp.float32)
    want = logits_from_hidden(params, cfg, h[:, -1:])
    _, cache = R.prefill(params, cfg, toks[:, :S], prefix_emb=prefix,
                         cache_len_cap=128, dtype=jnp.float32)
    got, _ = R.decode_step(params, cfg, cache, toks[:, S:S + 1],
                           dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("name", ["llama3.2-3b", "mamba2-2.7b",
                                  "recurrentgemma-9b"])
def test_multi_step_decode_finite(name):
    cfg = REDUCED[name]
    params = R.init_params(jax.random.PRNGKey(2), cfg)
    d = R.concrete_inputs(cfg, "prefill", 2, 16)
    logits, cache = R.prefill(params, cfg, d["tokens"],
                              prefix_emb=d.get("prefix_emb"),
                              cache_len_cap=64)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(4):
        logits, cache = R.decode_step(params, cfg, cache, tok)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    # the cache tracks its own per-request depths now
    assert np.asarray(cache.lengths).tolist() == [16 + 4] * 2


def test_param_specs_cover_params():
    """Every param leaf has a PartitionSpec of matching rank."""
    from jax.sharding import PartitionSpec
    for name in ASSIGNED_ARCHS:
        cfg = REDUCED[name]
        params = R.init_params(jax.random.PRNGKey(0), cfg)
        specs = R.param_specs(cfg)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
        assert len(flat_p) == len(flat_s), name
        pdef = jax.tree.structure(params)
        sdef = jax.tree.structure(
            specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
        assert pdef == sdef, name
