"""Property-based tests (hypothesis) on the scheduling system's
invariants.  Skipped (not a collection error) when hypothesis is not
installed — install via the ``dev`` extra in pyproject.toml."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import seesaw as SS
from repro.core import theory as T

TOTALS = st.integers(min_value=2 ** 20, max_value=2 ** 30)
B0S = st.sampled_from([8, 16, 32, 64, 128, 256])
ALPHAS = st.sampled_from([1.1, 1.5, 2.0, 4.0])
NCUTS = st.integers(min_value=1, max_value=12)
KINDS = st.sampled_from(["seesaw", "step", "cosine", "constant"])


@settings(max_examples=60, deadline=None)
@given(total=TOTALS, b0=B0S, alpha=ALPHAS, n_cuts=NCUTS, kind=KINDS)
def test_plan_invariants(total, b0, alpha, n_cuts, kind):
    p = SS.build_plan(kind=kind, base_lr=1.0, total_tokens=float(total),
                      warmup_frac=0.1, b0=b0, alpha=alpha, n_cuts=n_cuts)
    # phases tile [0, total]
    assert p.phases[0].start_tokens == 0.0
    assert p.phases[-1].end_tokens == pytest.approx(float(total))
    for a, b in zip(p.phases, p.phases[1:]):
        assert a.end_tokens == pytest.approx(b.start_tokens)
    # batch never shrinks, LR scale never grows
    for a, b in zip(p.phases, p.phases[1:]):
        assert b.batch_size >= a.batch_size
        assert b.lr_scale <= a.lr_scale + 1e-12
    # seesaw never violates Lemma 4
    if kind == "seesaw":
        assert p.alpha >= math.sqrt(p.beta) - 1e-9


@settings(max_examples=40, deadline=None)
@given(total=TOTALS, b0=B0S, alpha=ALPHAS, n_cuts=NCUTS,
       seq=st.sampled_from([128, 512, 1024, 4096]))
def test_token_conservation_under_ramp(total, b0, alpha, n_cuts, seq):
    """Seesaw consumes the same token budget as the reference, to within
    half a final-phase step (the discretization floor)."""
    see = SS.build_plan(kind="seesaw", base_lr=1.0,
                        total_tokens=float(total), warmup_frac=0.1,
                        b0=b0, alpha=alpha, n_cuts=n_cuts)
    sched = see.total_tokens_scheduled(seq)
    slack = see.phases[-1].batch_size * seq / 2 + 1
    assert abs(sched - total) <= slack


@settings(max_examples=40, deadline=None)
@given(total=TOTALS, b0=B0S, alpha=ALPHAS, n_cuts=NCUTS)
def test_seesaw_always_fewer_serial_steps(total, b0, alpha, n_cuts):
    see = SS.build_plan(kind="seesaw", base_lr=1.0,
                        total_tokens=float(total), warmup_frac=0.1,
                        b0=b0, alpha=alpha, n_cuts=n_cuts)
    ref = SS.build_plan(kind="step", base_lr=1.0,
                        total_tokens=float(total), warmup_frac=0.1,
                        b0=b0, alpha=alpha, n_cuts=n_cuts)
    assert see.total_steps(1024) <= ref.total_steps(1024)


@settings(max_examples=30, deadline=None)
@given(alpha=st.floats(min_value=1.01, max_value=4.0),
       beta=st.floats(min_value=1.0, max_value=16.0))
def test_divergence_guard_matches_lemma4(alpha, beta):
    risky = SS.divergence_risk(alpha, beta)
    assert risky == (alpha < math.sqrt(beta) - 1e-12)
    if risky:
        with pytest.raises(ValueError):
            SS.build_plan(kind="seesaw-general", base_lr=1.0,
                          total_tokens=1e6, warmup_frac=0.1, b0=8,
                          alpha=alpha, beta=beta, n_cuts=3)


@settings(max_examples=15, deadline=None)
@given(d=st.integers(min_value=10, max_value=60),
       a=st.floats(min_value=0.5, max_value=2.0),
       steps=st.integers(min_value=50, max_value=500))
def test_sgd_risk_monotone_envelope(d, a, steps):
    """Risk under a stable constant schedule never explodes and ends
    below its start (bias burn-down dominates at these step counts)."""
    lam = T.power_law_spectrum(d, a=a)
    eta = T.stability_eta(lam)
    risks, _, m = T.run_schedule(lam, 1.0, [T.TheoryPhase(eta, 8, steps)])
    start = 0.5 * float(np.dot(lam, np.full(d, 1.0 / d)))
    assert np.isfinite(risks[-1])
    assert risks[-1] < start * 1.01


@settings(max_examples=20, deadline=None)
@given(b0=B0S, alpha=st.sampled_from([1.5, 2.0, 3.0]),
       k=st.integers(min_value=1, max_value=6))
def test_effective_lr_invariant_on_seesaw_line(b0, alpha, k):
    """On the Seesaw line (cut √α, ramp ×α) the NSGD effective LR decays
    exactly like the reference α-step-decay: (√β/α_s)ᵏ = α^{-k/2}·...
    i.e. matches η̃ ∝ η√B."""
    a_s, b_s = math.sqrt(alpha), alpha
    eff = SS.effective_lr_ratio(a_s, b_s, k)
    assert eff == pytest.approx(1.0)   # most aggressive non-divergent ramp
