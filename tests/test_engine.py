"""Phase execution engine tests: fused-dispatch equivalence, device-side
LR schedule, microbatch geometry, chunked loading, and phase-aware
checkpoint resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.core import schedules as S
from repro.data import MarkovLM, PhaseDataLoader
from repro.train import engine as E
from repro.train.trainer import Trainer

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                   n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                   vocab_size=128, max_seq_len=64, rope_theta=1e4)


def _cfg(kind="seesaw", steps=40, b0=4, **kw):
    return RunConfig(model=TINY,
                     schedule=ScheduleConfig(kind=kind, base_lr=1e-3,
                                             alpha=2.0, n_cuts=2),
                     optimizer=OptimizerConfig(kind="adamw"),
                     seq_len=32, global_batch_size=b0,
                     total_tokens=32 * b0 * steps, remat=False, **kw)


def _run(kind="seesaw", fuse_steps=1, steps=40):
    cfg = _cfg(kind=kind, steps=steps)
    tr = Trainer(cfg, fuse_steps=fuse_steps)
    loader = PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32)
    tr.run(loader)
    return tr


class TestFusedEquivalence:
    @pytest.mark.parametrize("k", [4, 16])
    def test_fused_matches_eager(self, k):
        """K-step fused dispatch trains identically to eager (K=1):
        final params are BITWISE equal (the update path runs the same
        scan body), and the logged loss trajectory matches to a couple
        of f32 ulps (XLA fuses the scalar metric readout differently
        per trip count; the metric reduction order is the only
        difference, and it never feeds back into training)."""
        eager = _run(fuse_steps=1)
        fused = _run(fuse_steps=k)
        for a, b in zip(jax.tree.leaves(eager.state.params),
                        jax.tree.leaves(fused.state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(eager.state.opt_state),
                        jax.tree.leaves(fused.state.opt_state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        le = np.asarray([h["loss"] for h in eager.history], np.float32)
        lf = np.asarray([h["loss"] for h in fused.history], np.float32)
        assert len(le) == len(lf)
        ulp = np.maximum(np.spacing(le), np.spacing(lf))
        assert np.all(np.abs(le - lf) <= 2 * ulp)
        np.testing.assert_array_equal(
            [h["lr"] for h in eager.history],
            [h["lr"] for h in fused.history])
        assert ([h["batch_size"] for h in eager.history]
                == [h["batch_size"] for h in fused.history])

    def test_fused_chunks_respect_phase_boundaries(self):
        """Every fused chunk is single-phase: phase batch sizes in the
        history change exactly where the plan says."""
        tr = _run(fuse_steps=16)
        steps = tr.plan.steps_per_phase(32)
        edges = np.cumsum(steps)
        sizes = [h["batch_size"] for h in tr.history]
        for edge, phase in zip(edges[:-1], tr.plan.phases[:-1]):
            assert sizes[edge - 1] == phase.batch_size
            assert sizes[edge] != phase.batch_size

    def test_one_compile_per_batch_size(self):
        tr = _run(fuse_steps=1)
        sizes = {h["batch_size"] for h in tr.history}
        assert len(tr._step_cache) == len(sizes) >= 3


class TestDeviceLR:
    def test_piecewise_matches_plan_per_step(self):
        """The traced LR evaluated at every realized step start equals
        base_lr × (scale of the phase that step belongs to)."""
        cfg = _cfg()
        tr = Trainer(cfg)
        lr_fn = tr.engine.lr_fn
        tok = 0.0
        for phase, n in zip(tr.plan.phases,
                            tr.plan.steps_per_phase(32)):
            for _ in range(n):
                if tok >= tr.plan.warmup_tokens:
                    expect = tr.plan.base_lr * phase.lr_scale
                    assert float(lr_fn(tok)) == pytest.approx(
                        expect, rel=1e-6)
                else:
                    assert float(lr_fn(tok)) == pytest.approx(
                        tr.plan.base_lr * tok
                        / max(tr.plan.warmup_tokens, 1.0), rel=1e-5)
                tok += phase.batch_size * 32

    def test_cosine_matches_host_curve(self):
        cfg = _cfg(kind="cosine")
        tr = Trainer(cfg)
        for tok in [0.0, 500.0, 2000.0, 5000.0]:
            assert float(tr.engine.lr_fn(tok)) == pytest.approx(
                tr.lr_at(tok), rel=1e-6)

    def test_piecewise_lr_indexing(self):
        lr = S.piecewise_lr(1.0, 0.0, [100.0, 200.0, 300.0],
                            [1.0, 0.5, 0.25])
        assert float(lr(0.0)) == 1.0
        assert float(lr(99.0)) == 1.0
        assert float(lr(100.0)) == 0.5       # boundary → next phase
        assert float(lr(250.0)) == 0.25
        assert float(lr(1000.0)) == 0.25     # clamped to last phase


class FakeMesh:
    """Duck-typed mesh: only .shape is consulted by micro_batches."""
    def __init__(self, **axes):
        self.shape = dict(axes)


class TestMicroBatchGeometry:
    def test_micro_divides_per_device_batch(self):
        """Regression: global batch 12 on 4 data devices with
        max_device_batch=2.  micro=2 divides the *global* batch but
        leaves a fractional per-device microbatch (12/2/4 = 1.5); the
        engine must pick micro=3 (12/3/4 = 1 sequence per device)."""
        cfg = _cfg()
        tr = Trainer(cfg, mesh=FakeMesh(data=4), max_device_batch=2)
        micro = tr._micro(12)
        assert micro == 3
        assert 12 % micro == 0
        assert (12 // micro) % 4 == 0

    def test_micro_impossible_batch_raises(self):
        """Regression: global batch 6 on 4 data devices with
        max_device_batch=2 has NO valid accumulation count (6 is not
        divisible by 4 at any micro).  The old loop exited at
        ``micro == batch_size`` and silently returned 6 — a fractional
        1.5-sequence per-device share.  The engine must raise, naming
        the geometry."""
        tr = Trainer(_cfg(), mesh=FakeMesh(data=4), max_device_batch=2)
        with pytest.raises(ValueError, match=r"6.*4 data devices"):
            tr._micro(6)

    def test_micro_single_device(self):
        tr = Trainer(_cfg(), max_device_batch=2)
        assert tr._micro(8) == 4
        assert tr._micro(2) == 1

    def test_micro_multi_pod_axes(self):
        tr = Trainer(_cfg(), mesh=FakeMesh(pod=2, data=2),
                     max_device_batch=4, multi_pod=True)
        micro = tr._micro(16)
        assert 16 % micro == 0 and (16 // micro) % 4 == 0


class TestChunkedLoader:
    def test_chunks_equal_step_stream(self):
        """iter_chunks(k) is a reshape of the per-step stream — same
        sequences, same order, same sharded values.  Every chunk has
        leading dim exactly k (tail chunks are padded and report m < k
        real steps); only the m real steps belong to the stream."""
        plan = Trainer(_cfg()).plan
        l1 = PhaseDataLoader(MarkovLM(128, seed=0), plan, 32)
        l2 = PhaseDataLoader(MarkovLM(128, seed=0), plan, 32)
        flat = [np.asarray(b["tokens"]) for _, _, b in l1]
        chunked = []
        for phase, chunk, m in l2.iter_chunks(4):
            arr = np.asarray(chunk["tokens"])
            assert arr.shape[0] == 4 and 1 <= m <= 4
            chunked.extend(arr[i] for i in range(m))
        assert len(flat) == len(chunked)
        for a, b in zip(flat, chunked):
            np.testing.assert_array_equal(a, b)

    def test_resume_positions_stream(self):
        plan = Trainer(_cfg()).plan
        src = MarkovLM(128, seed=0)
        full = list(PhaseDataLoader(src, plan, 32))
        # resume right where step 5 starts
        tok5 = sum(p.batch_size * 32 for p, _, _ in full[:5])
        tail = list(PhaseDataLoader(src, plan, 32).resume(tok5))
        assert len(tail) == len(full) - 5
        np.testing.assert_array_equal(
            np.asarray(tail[0][2]["tokens"]),
            np.asarray(full[5][2]["tokens"]))

    def test_resume_rejects_off_boundary_tokens(self):
        plan = Trainer(_cfg()).plan
        loader = PhaseDataLoader(MarkovLM(128, seed=0), plan, 32)
        with pytest.raises(ValueError):
            loader.resume(17.0)


class TestMergedChunkStream:
    def test_step_plan_single_executable_bitwise(self):
        """'step' plans (β=1) keep one batch size, so every phase
        merges into one contiguous chunk stream: 40 steps at K=16 run
        as chunks of 16/16/8-padded through ONE compiled program, and
        params stay bitwise equal to the eager per-step reference."""
        eager = _run(kind="step", fuse_steps=1)
        fused = _run(kind="step", fuse_steps=16)
        for a, b in zip(jax.tree.leaves(eager.state.params),
                        jax.tree.leaves(fused.state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert len(fused._step_cache) == 1
        assert [key[2] for key in fused._step_cache] == [16]
        # per-step phase/LR attribution is exact across the merged
        # boundaries a chunk may straddle
        assert ([h["phase"] for h in eager.history]
                == [h["phase"] for h in fused.history])
        assert ([h["lr"] for h in eager.history]
                == [h["lr"] for h in fused.history])

    def test_tail_padding_conserves_steps_and_tokens(self):
        """Phase step counts that are not multiples of K: padding must
        neither drop nor duplicate steps, and the integer token carry
        must land exactly on the plan's scheduled total."""
        tr = _run(kind="seesaw", fuse_steps=16)
        assert len(tr.history) == tr.plan.total_steps(32)
        assert isinstance(tr.state.tokens_seen, int)
        assert tr.state.tokens_seen == int(
            tr.plan.total_tokens_scheduled(32))
        toks = [h["tokens"] for h in tr.history]
        assert toks[-1] == tr.state.tokens_seen
        assert all(b > a for a, b in zip(toks, toks[1:]))

    def test_merged_segments_structure(self):
        plan = Trainer(_cfg(kind="step")).plan
        segs = plan.merged_segments(32)
        assert len(segs) == 1                # β=1: one segment
        _, entries = segs[0]
        assert sum(n for _, n in entries) == plan.total_steps(32)
        assert len(entries) == len(plan.phases)
        plan2 = Trainer(_cfg(kind="seesaw")).plan
        assert len(plan2.merged_segments(32)) == len(plan2.phases)

    def test_max_steps_budget_reuses_padded_executable(self):
        """A max_steps budget lowers n_valid on the padded chunk
        instead of slicing it, so truncation never compiles a new
        program shape."""
        tr = Trainer(_cfg(kind="step"), fuse_steps=16)
        tr.run(PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32),
               max_steps=5)
        assert len(tr.history) == 5
        assert {key[2] for key in tr._step_cache} == {16}


class TestExactTokenCarry:
    def test_lr_cut_exact_beyond_2p24_tokens_per_chunk(self):
        """Regression for the old f32 token carry: with tokens_per_step
        an odd number > 2^23, a 6-step chunk spans > 2^24 tokens and
        the step-3 cut sits at an odd token count f32 cannot represent
        — an f32 accumulator drifts and can land the cut a step off.
        The int32 step carry + step-indexed cut selection place it
        exactly."""
        tps = 2 ** 23 + 1
        k = 6
        ends = [3 * tps, 6 * tps]
        lr_fn = S.piecewise_lr(1.0, 0.0, ends, [1.0, 0.5],
                               phase_end_steps=[3, 6])

        def stub(params, opt_state, batch, lr):
            return params + lr, opt_state, {"loss": jnp.float32(0.0)}

        fused = E.make_fused_step(stub, lr_fn, tps)
        batches = jnp.zeros((k, 1), jnp.float32)
        _, _, m = jax.jit(fused)(jnp.float32(0.0), jnp.float32(0.0),
                                 jnp.float32(0.0), jnp.int32(0),
                                 jnp.int32(k), batches)
        np.testing.assert_array_equal(
            np.asarray(m["lr"]),
            np.asarray([1.0, 1.0, 1.0, 0.5, 0.5, 0.5], np.float32))

    def test_n_valid_masks_padded_tail(self):
        """Steps at i >= n_valid leave params and opt state untouched
        (bitwise) and report zeroed metrics."""
        lr_fn = S.constant_lr(0.5)

        def stub(params, opt_state, batch, lr):
            return params + lr, opt_state + 1, {"loss": jnp.float32(1.0)}

        fused = E.make_fused_step(stub, lr_fn, 128)
        batches = jnp.zeros((4, 1), jnp.float32)
        p, o, m = jax.jit(fused)(jnp.float32(0.0), jnp.float32(0.0),
                                 jnp.float32(0.0), jnp.int32(0),
                                 jnp.int32(2), batches)
        assert float(p) == pytest.approx(1.0)      # 2 × lr=0.5
        assert float(o) == 2.0
        np.testing.assert_array_equal(
            np.asarray(m["loss"]), [1.0, 1.0, 0.0, 0.0])

    def test_unknown_step_sentinel_covers_whole_chunk(self):
        """A caller without a global step index (step0 = -1) must get
        the token-compare fallback for EVERY step of the chunk — a
        naive ``step0 + i`` turns non-negative from i=1 on and would
        silently select phase 0's LR mid-plan."""
        tps = 64
        lr_fn = S.piecewise_lr(1.0, 0.0, [192, 384], [1.0, 0.5],
                               phase_end_steps=[3, 6])

        def stub(params, opt_state, batch, lr):
            return params, opt_state, {"loss": jnp.float32(0.0)}

        fused = E.make_fused_step(stub, lr_fn, tps)
        batches = jnp.zeros((4, 1), jnp.float32)
        # resume mid-run at token 192 = start of phase 1, step unknown
        _, _, m = jax.jit(fused)(jnp.float32(0.0), jnp.float32(0.0),
                                 jnp.float32(192.0), jnp.int32(-1),
                                 jnp.int32(4), batches)
        np.testing.assert_array_equal(np.asarray(m["lr"]),
                                      np.full(4, 0.5, np.float32))

    def test_run_chunk_rejects_int32_token_overflow(self):
        tr = Trainer(_cfg())
        huge = {"tokens": jax.ShapeDtypeStruct((2 ** 16, 2 ** 11, 32),
                                               jnp.int32)}
        with pytest.raises(ValueError, match="int32"):
            tr.engine.run_chunk(None, None, 0, huge)


class TestPhaseCheckpoint:
    def test_roundtrip_across_phase_boundary(self, tmp_path):
        """Save mid-plan (inside phase 1), resume in a fresh trainer:
        the resumed (lr, batch_size, phase, loss) trajectory matches an
        uninterrupted run step-for-step."""
        cfg = _cfg(kind="seesaw")
        src = MarkovLM(128, seed=0)

        tr_full = Trainer(cfg)
        tr_full.run(PhaseDataLoader(src, tr_full.plan, 32))

        steps0 = tr_full.plan.steps_per_phase(32)[0]
        mid = steps0 + 1                       # one step into phase 1
        tr_a = Trainer(cfg)
        tr_a.run(PhaseDataLoader(src, tr_a.plan, 32), max_steps=mid)
        assert tr_a.history[-1]["phase"] == 1
        path = str(tmp_path / "mid.npz")
        tr_a.save_checkpoint(path)

        tr_b = Trainer(cfg)
        meta = tr_b.restore_checkpoint(path)
        assert meta["phase"] == 1
        assert meta["batch_size"] == tr_b.plan.phases[1].batch_size
        loader = PhaseDataLoader(src, tr_b.plan, 32).resume(
            tr_b.state.tokens_seen)
        tr_b.run(loader)

        resumed = tr_b.history
        ref = tr_full.history[mid:]
        assert len(resumed) == len(ref)
        for a, b in zip(ref, resumed):
            assert a["step"] == b["step"]
            assert a["phase"] == b["phase"]
            assert a["batch_size"] == b["batch_size"]
            assert a["lr"] == b["lr"]
            assert a["tokens"] == b["tokens"]
            np.testing.assert_array_equal(a["loss"], b["loss"])

    def test_save_at_exact_phase_boundary(self, tmp_path):
        """A checkpoint saved on the realized phase boundary (the
        module docstring's 'natural checkpoint point') must record the
        NEXT phase — the one the first resumed step trains in — using
        the step-quantized boundaries the loader/device-LR use, not
        the plan's ideal token cut points (which can sit a carry
        past)."""
        cfg = _cfg(kind="seesaw")
        tr = Trainer(cfg)
        steps0 = tr.plan.steps_per_phase(32)[0]
        tr.run(PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32),
               max_steps=steps0)
        path = str(tmp_path / "boundary.npz")
        tr.save_checkpoint(path)
        tr2 = Trainer(cfg)
        meta = tr2.restore_checkpoint(path)
        assert meta["phase"] == 1
        assert meta["batch_size"] == tr2.plan.phases[1].batch_size
        loader = PhaseDataLoader(MarkovLM(128, seed=0), tr2.plan,
                                 32).resume(tr2.state.tokens_seen)
        tr2.run(loader, max_steps=steps0 + 1)
        assert tr2.history[-1]["phase"] == 1
        assert tr2.history[-1]["batch_size"] == meta["batch_size"]

    def test_roundtrip_across_merged_boundary_fused(self, tmp_path):
        """Save mid-run inside a *merged* segment (a 'step' plan whose
        phases all share one batch size), resume with fused K=16: the
        resumed trajectory continues the uninterrupted run bitwise —
        even though the resumed run's chunk boundaries differ — and
        the resumed engine still compiles a single K=16 program."""
        cfg = _cfg(kind="step")
        src = MarkovLM(128, seed=0)
        full = Trainer(cfg, fuse_steps=16)
        full.run(PhaseDataLoader(src, full.plan, 32))

        steps0 = full.plan.steps_per_phase(32)[0]
        mid = steps0 + 1                     # one step into phase 1
        tr_a = Trainer(cfg, fuse_steps=16)
        tr_a.run(PhaseDataLoader(src, tr_a.plan, 32), max_steps=mid)
        assert tr_a.history[-1]["phase"] == 1
        path = str(tmp_path / "merged.npz")
        tr_a.save_checkpoint(path)

        tr_b = Trainer(cfg, fuse_steps=16)
        meta = tr_b.restore_checkpoint(path)
        assert meta["phase"] == 1
        assert isinstance(tr_b.state.tokens_seen, int)
        tr_b.run(PhaseDataLoader(src, tr_b.plan, 32).resume(
            tr_b.state.tokens_seen))
        ref = full.history[mid:]
        assert len(tr_b.history) == len(ref)
        for x, y in zip(ref, tr_b.history):
            assert x["step"] == y["step"]
            assert x["phase"] == y["phase"]
            assert x["lr"] == y["lr"]
            assert x["tokens"] == y["tokens"]
            np.testing.assert_array_equal(x["loss"], y["loss"])
        assert [key[2] for key in tr_b._step_cache] == [16]
        for p, q in zip(jax.tree.leaves(full.state.params),
                        jax.tree.leaves(tr_b.state.params)):
            np.testing.assert_array_equal(np.asarray(p), np.asarray(q))

    def test_log_every_zero_logs_every_step(self):
        cfg = _cfg(steps=12, log_every=0)
        tr = Trainer(cfg)
        seen = []
        tr.run(PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32),
               max_steps=4, log_cb=seen.append)
        assert len(seen) == 4

    def test_restore_rejects_mismatched_plan(self, tmp_path):
        cfg = _cfg(kind="seesaw")
        tr = Trainer(cfg)
        steps0 = tr.plan.steps_per_phase(32)[0]
        tr.run(PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32),
               max_steps=steps0 + 1)
        path = str(tmp_path / "mid.npz")
        tr.save_checkpoint(path)
        other = Trainer(_cfg(kind="constant"))
        with pytest.raises(ValueError, match="schedule mismatch"):
            other.restore_checkpoint(path)


class TestSingleStepBuilder:
    def test_grad_step_signature(self):
        """The engine step is usable standalone (launch.steps path)."""
        from repro.optim import optimizers as O
        from repro.models import registry as R
        opt = O.adamw()
        step = E.make_grad_step(TINY, opt, dtype=jnp.float32,
                                remat=False)
        params = R.init_params(jax.random.PRNGKey(0), TINY)
        st = opt.init(params)
        batch = R.concrete_inputs(TINY, "train", 4, 32)
        p, s, metrics = jax.jit(step)(params, st, batch,
                                      jnp.asarray(1e-3))
        assert "loss" in metrics and "grad_norm" in metrics
        assert np.isfinite(float(metrics["loss"]))

    def test_scan_accum_matches_unrolled_micro(self):
        """lax.scan microbatch accumulation ≡ single full batch under a
        linear optimizer (order-of-summation noise only)."""
        from repro.optim import optimizers as O
        from repro.models import registry as R
        opt = O.sgd(grad_clip=0.0)
        s1 = E.make_grad_step(TINY, opt, micro_batches=1,
                              dtype=jnp.float32, remat=False)
        s4 = E.make_grad_step(TINY, opt, micro_batches=4,
                              dtype=jnp.float32, remat=False)
        params = R.init_params(jax.random.PRNGKey(0), TINY)
        st = opt.init(params)
        batch = R.concrete_inputs(TINY, "train", 8, 32)
        p1, _, m1 = s1(params, st, batch, jnp.asarray(1e-1))
        p4, _, m4 = s4(params, st, batch, jnp.asarray(1e-1))
        assert float(m1["loss"]) == pytest.approx(float(m4["loss"]),
                                                  rel=1e-4)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4, rtol=1e-3)
