"""Phase execution engine tests: fused-dispatch equivalence, device-side
LR schedule, microbatch geometry, chunked loading, and phase-aware
checkpoint resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.core import schedules as S
from repro.data import MarkovLM, PhaseDataLoader
from repro.train import engine as E
from repro.train.trainer import Trainer

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                   n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                   vocab_size=128, max_seq_len=64, rope_theta=1e4)


def _cfg(kind="seesaw", steps=40, b0=4, **kw):
    return RunConfig(model=TINY,
                     schedule=ScheduleConfig(kind=kind, base_lr=1e-3,
                                             alpha=2.0, n_cuts=2),
                     optimizer=OptimizerConfig(kind="adamw"),
                     seq_len=32, global_batch_size=b0,
                     total_tokens=32 * b0 * steps, remat=False, **kw)


def _run(kind="seesaw", fuse_steps=1, steps=40):
    cfg = _cfg(kind=kind, steps=steps)
    tr = Trainer(cfg, fuse_steps=fuse_steps)
    loader = PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32)
    tr.run(loader)
    return tr


class TestFusedEquivalence:
    @pytest.mark.parametrize("k", [4, 16])
    def test_fused_matches_eager(self, k):
        """K-step fused dispatch trains identically to eager (K=1):
        final params are BITWISE equal (the update path runs the same
        scan body), and the logged loss trajectory matches to a couple
        of f32 ulps (XLA fuses the scalar metric readout differently
        per trip count; the metric reduction order is the only
        difference, and it never feeds back into training)."""
        eager = _run(fuse_steps=1)
        fused = _run(fuse_steps=k)
        for a, b in zip(jax.tree.leaves(eager.state.params),
                        jax.tree.leaves(fused.state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(eager.state.opt_state),
                        jax.tree.leaves(fused.state.opt_state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        le = np.asarray([h["loss"] for h in eager.history], np.float32)
        lf = np.asarray([h["loss"] for h in fused.history], np.float32)
        assert len(le) == len(lf)
        ulp = np.maximum(np.spacing(le), np.spacing(lf))
        assert np.all(np.abs(le - lf) <= 2 * ulp)
        np.testing.assert_array_equal(
            [h["lr"] for h in eager.history],
            [h["lr"] for h in fused.history])
        assert ([h["batch_size"] for h in eager.history]
                == [h["batch_size"] for h in fused.history])

    def test_fused_chunks_respect_phase_boundaries(self):
        """Every fused chunk is single-phase: phase batch sizes in the
        history change exactly where the plan says."""
        tr = _run(fuse_steps=16)
        steps = tr.plan.steps_per_phase(32)
        edges = np.cumsum(steps)
        sizes = [h["batch_size"] for h in tr.history]
        for edge, phase in zip(edges[:-1], tr.plan.phases[:-1]):
            assert sizes[edge - 1] == phase.batch_size
            assert sizes[edge] != phase.batch_size

    def test_one_compile_per_batch_size(self):
        tr = _run(fuse_steps=1)
        sizes = {h["batch_size"] for h in tr.history}
        assert len(tr._step_cache) == len(sizes) >= 3


class TestDeviceLR:
    def test_piecewise_matches_plan_per_step(self):
        """The traced LR evaluated at every realized step start equals
        base_lr × (scale of the phase that step belongs to)."""
        cfg = _cfg()
        tr = Trainer(cfg)
        lr_fn = tr.engine.lr_fn
        tok = 0.0
        for phase, n in zip(tr.plan.phases,
                            tr.plan.steps_per_phase(32)):
            for _ in range(n):
                if tok >= tr.plan.warmup_tokens:
                    expect = tr.plan.base_lr * phase.lr_scale
                    assert float(lr_fn(tok)) == pytest.approx(
                        expect, rel=1e-6)
                else:
                    assert float(lr_fn(tok)) == pytest.approx(
                        tr.plan.base_lr * tok
                        / max(tr.plan.warmup_tokens, 1.0), rel=1e-5)
                tok += phase.batch_size * 32

    def test_cosine_matches_host_curve(self):
        cfg = _cfg(kind="cosine")
        tr = Trainer(cfg)
        for tok in [0.0, 500.0, 2000.0, 5000.0]:
            assert float(tr.engine.lr_fn(tok)) == pytest.approx(
                tr.lr_at(tok), rel=1e-6)

    def test_piecewise_lr_indexing(self):
        lr = S.piecewise_lr(1.0, 0.0, [100.0, 200.0, 300.0],
                            [1.0, 0.5, 0.25])
        assert float(lr(0.0)) == 1.0
        assert float(lr(99.0)) == 1.0
        assert float(lr(100.0)) == 0.5       # boundary → next phase
        assert float(lr(250.0)) == 0.25
        assert float(lr(1000.0)) == 0.25     # clamped to last phase


class FakeMesh:
    """Duck-typed mesh: only .shape is consulted by micro_batches."""
    def __init__(self, **axes):
        self.shape = dict(axes)


class TestMicroBatchGeometry:
    def test_micro_divides_per_device_batch(self):
        """Regression: global batch 12 on 4 data devices with
        max_device_batch=2.  micro=2 divides the *global* batch but
        leaves a fractional per-device microbatch (12/2/4 = 1.5); the
        engine must pick micro=3 (12/3/4 = 1 sequence per device)."""
        cfg = _cfg()
        tr = Trainer(cfg, mesh=FakeMesh(data=4), max_device_batch=2)
        micro = tr._micro(12)
        assert micro == 3
        assert 12 % micro == 0
        assert (12 // micro) % 4 == 0

    def test_micro_single_device(self):
        tr = Trainer(_cfg(), max_device_batch=2)
        assert tr._micro(8) == 4
        assert tr._micro(2) == 1

    def test_micro_multi_pod_axes(self):
        tr = Trainer(_cfg(), mesh=FakeMesh(pod=2, data=2),
                     max_device_batch=4, multi_pod=True)
        micro = tr._micro(16)
        assert 16 % micro == 0 and (16 // micro) % 4 == 0


class TestChunkedLoader:
    def test_chunks_equal_step_stream(self):
        """iter_chunks(k) is a reshape of the per-step stream — same
        sequences, same order, same sharded values."""
        plan = Trainer(_cfg()).plan
        l1 = PhaseDataLoader(MarkovLM(128, seed=0), plan, 32)
        l2 = PhaseDataLoader(MarkovLM(128, seed=0), plan, 32)
        flat = [np.asarray(b["tokens"]) for _, _, b in l1]
        chunked = []
        for phase, chunk, m in l2.iter_chunks(4):
            arr = np.asarray(chunk["tokens"])
            assert arr.shape[0] == m
            chunked.extend(arr[i] for i in range(m))
        assert len(flat) == len(chunked)
        for a, b in zip(flat, chunked):
            np.testing.assert_array_equal(a, b)

    def test_resume_positions_stream(self):
        plan = Trainer(_cfg()).plan
        src = MarkovLM(128, seed=0)
        full = list(PhaseDataLoader(src, plan, 32))
        # resume right where step 5 starts
        tok5 = sum(p.batch_size * 32 for p, _, _ in full[:5])
        tail = list(PhaseDataLoader(src, plan, 32).resume(tok5))
        assert len(tail) == len(full) - 5
        np.testing.assert_array_equal(
            np.asarray(tail[0][2]["tokens"]),
            np.asarray(full[5][2]["tokens"]))

    def test_resume_rejects_off_boundary_tokens(self):
        plan = Trainer(_cfg()).plan
        loader = PhaseDataLoader(MarkovLM(128, seed=0), plan, 32)
        with pytest.raises(ValueError):
            loader.resume(17.0)


class TestPhaseCheckpoint:
    def test_roundtrip_across_phase_boundary(self, tmp_path):
        """Save mid-plan (inside phase 1), resume in a fresh trainer:
        the resumed (lr, batch_size, phase, loss) trajectory matches an
        uninterrupted run step-for-step."""
        cfg = _cfg(kind="seesaw")
        src = MarkovLM(128, seed=0)

        tr_full = Trainer(cfg)
        tr_full.run(PhaseDataLoader(src, tr_full.plan, 32))

        steps0 = tr_full.plan.steps_per_phase(32)[0]
        mid = steps0 + 1                       # one step into phase 1
        tr_a = Trainer(cfg)
        tr_a.run(PhaseDataLoader(src, tr_a.plan, 32), max_steps=mid)
        assert tr_a.history[-1]["phase"] == 1
        path = str(tmp_path / "mid.npz")
        tr_a.save_checkpoint(path)

        tr_b = Trainer(cfg)
        meta = tr_b.restore_checkpoint(path)
        assert meta["phase"] == 1
        assert meta["batch_size"] == tr_b.plan.phases[1].batch_size
        loader = PhaseDataLoader(src, tr_b.plan, 32).resume(
            tr_b.state.tokens_seen)
        tr_b.run(loader)

        resumed = tr_b.history
        ref = tr_full.history[mid:]
        assert len(resumed) == len(ref)
        for a, b in zip(ref, resumed):
            assert a["step"] == b["step"]
            assert a["phase"] == b["phase"]
            assert a["batch_size"] == b["batch_size"]
            assert a["lr"] == b["lr"]
            assert a["tokens"] == b["tokens"]
            np.testing.assert_array_equal(a["loss"], b["loss"])

    def test_save_at_exact_phase_boundary(self, tmp_path):
        """A checkpoint saved on the realized phase boundary (the
        module docstring's 'natural checkpoint point') must record the
        NEXT phase — the one the first resumed step trains in — using
        the step-quantized boundaries the loader/device-LR use, not
        the plan's ideal token cut points (which can sit a carry
        past)."""
        cfg = _cfg(kind="seesaw")
        tr = Trainer(cfg)
        steps0 = tr.plan.steps_per_phase(32)[0]
        tr.run(PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32),
               max_steps=steps0)
        path = str(tmp_path / "boundary.npz")
        tr.save_checkpoint(path)
        tr2 = Trainer(cfg)
        meta = tr2.restore_checkpoint(path)
        assert meta["phase"] == 1
        assert meta["batch_size"] == tr2.plan.phases[1].batch_size
        loader = PhaseDataLoader(MarkovLM(128, seed=0), tr2.plan,
                                 32).resume(tr2.state.tokens_seen)
        tr2.run(loader, max_steps=steps0 + 1)
        assert tr2.history[-1]["phase"] == 1
        assert tr2.history[-1]["batch_size"] == meta["batch_size"]

    def test_log_every_zero_logs_every_step(self):
        cfg = _cfg(steps=12, log_every=0)
        tr = Trainer(cfg)
        seen = []
        tr.run(PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32),
               max_steps=4, log_cb=seen.append)
        assert len(seen) == 4

    def test_restore_rejects_mismatched_plan(self, tmp_path):
        cfg = _cfg(kind="seesaw")
        tr = Trainer(cfg)
        steps0 = tr.plan.steps_per_phase(32)[0]
        tr.run(PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32),
               max_steps=steps0 + 1)
        path = str(tmp_path / "mid.npz")
        tr.save_checkpoint(path)
        other = Trainer(_cfg(kind="constant"))
        with pytest.raises(ValueError, match="schedule mismatch"):
            other.restore_checkpoint(path)


class TestSingleStepBuilder:
    def test_grad_step_signature(self):
        """The engine step is usable standalone (launch.steps path)."""
        from repro.optim import optimizers as O
        from repro.models import registry as R
        opt = O.adamw()
        step = E.make_grad_step(TINY, opt, dtype=jnp.float32,
                                remat=False)
        params = R.init_params(jax.random.PRNGKey(0), TINY)
        st = opt.init(params)
        batch = R.concrete_inputs(TINY, "train", 4, 32)
        p, s, metrics = jax.jit(step)(params, st, batch,
                                      jnp.asarray(1e-3))
        assert "loss" in metrics and "grad_norm" in metrics
        assert np.isfinite(float(metrics["loss"]))

    def test_scan_accum_matches_unrolled_micro(self):
        """lax.scan microbatch accumulation ≡ single full batch under a
        linear optimizer (order-of-summation noise only)."""
        from repro.optim import optimizers as O
        from repro.models import registry as R
        opt = O.sgd(grad_clip=0.0)
        s1 = E.make_grad_step(TINY, opt, micro_batches=1,
                              dtype=jnp.float32, remat=False)
        s4 = E.make_grad_step(TINY, opt, micro_batches=4,
                              dtype=jnp.float32, remat=False)
        params = R.init_params(jax.random.PRNGKey(0), TINY)
        st = opt.init(params)
        batch = R.concrete_inputs(TINY, "train", 8, 32)
        p1, _, m1 = s1(params, st, batch, jnp.asarray(1e-1))
        p4, _, m4 = s4(params, st, batch, jnp.asarray(1e-1))
        assert float(m1["loss"]) == pytest.approx(float(m4["loss"]),
                                                  rel=1e-4)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4, rtol=1e-3)
