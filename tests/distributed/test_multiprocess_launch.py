"""True 2-process ``jax.distributed`` launch (the PR 5 tentpole proof).

Two real OS processes (1 CPU device each) form a process group over a
local TCP coordinator through ``launch.train.maybe_init_distributed``
— the exact wiring the production launcher uses — and train the
Seesaw batch ramp with per-host data feeding on a global ``(2, 1)``
data x model mesh.  The run is checkpointed mid-ramp exactly on the
first merged-segment (batch-size) boundary into the sharded streaming
directory format, resumed in a fresh trainer, and the final params
must match the UNINTERRUPTED two-process run **bitwise** (float32 per
the bf16-drift note).  The single-process run of the same workload on
the same mesh is compared within collective-rounding distance instead:
XLA's in-process all-reduce and gloo's cross-process all-reduce round
the last ulp differently (~1e-6 relative over this run, with per-step
loss histories still identical), so cross-topology bitwise equality
is not physical.  Along the way the script proves
no process ever materializes a full replica during save: every
device→host transfer goes through ``checkpoint._to_host`` and is
bounded by the chunk size.

A second case saves a *data-sharded* array from both processes, so the
one-writer-per-block protocol (each process streams only its
addressable replica-0 shards; process 0 commits a manifest naming
files it did not write) is exercised cross-process, and the restored
global array must reassemble bitwise on both processes.
"""
import pytest

# both modes share one cfg so the reference and distributed runs are
# the same workload; argv: mode ("ref"|"dist"), ckpt dir, ref npz path
SCRIPT = r"""
import json, os, sys
pid, nproc, port = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
mode, ckdir, refpath = sys.argv[4], sys.argv[5], sys.argv[6]

from repro.launch.train import maybe_init_distributed
if mode == "dist":
    assert maybe_init_distributed(f"127.0.0.1:{port}", nproc, pid)

import jax
import numpy as np
from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.data import MarkovLM, PhaseDataLoader
from repro.launch.mesh import assert_per_host_row_blocks
from repro.launch.steps import validate_feeding
from repro.train import checkpoint as CKPT
from repro.train.trainer import Trainer

SEQ = 32
TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                   d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                   d_ff=128, vocab_size=128, max_seq_len=64,
                   rope_theta=1e4)
cfg = RunConfig(
    model=TINY,
    schedule=ScheduleConfig(kind="seesaw", base_lr=1e-3, alpha=2.0,
                            n_cuts=2),
    optimizer=OptimizerConfig(kind="adamw"),
    seq_len=SEQ, global_batch_size=8, total_tokens=SEQ * 8 * 24,
    remat=False, dtype="float32")
mesh = jax.make_mesh((2, 1), ("data", "model"))
assert_per_host_row_blocks(mesh)


def make():
    tr = Trainer(cfg, mesh=mesh, fuse_steps=4)
    validate_feeding(tr.plan, mesh)
    loader = PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, SEQ,
                             mesh=mesh, per_host=True)
    return tr, loader


def host_params(tr):
    # params are replicated over the data axis: the local replica
    # block IS the full leaf (never np.asarray the global array — it
    # spans the other process's device)
    return [np.asarray(x.addressable_shards[0].data)
            for x in jax.tree.leaves(tr.state.params)]


if mode == "ref":
    tr, loader = make()
    tr.run(loader)
    np.savez(refpath, *host_params(tr))
    print(json.dumps({"steps": len(tr.history),
                      "n_devices": jax.device_count()}))
    sys.exit(0)

assert jax.process_count() == 2 and jax.device_count() == 2

# -- uninterrupted 2-process baseline: the bitwise reference for the
# interrupted+resumed run (same topology, same collectives) ----------- #
tr_full, loader_full = make()
tr_full.run(loader_full)
full_params = host_params(tr_full)

# -- interrupted leg: train to the first batch-size boundary ---------- #
tr, loader = make()
steps0 = tr.plan.steps_per_phase(SEQ)[0]
tr.run(loader, max_steps=steps0)
assert tr.state.step == steps0

transfers = []
orig = CKPT._to_host


def spy(x):
    h = orig(x)
    transfers.append(h.nbytes)
    return h


CKPT._to_host = spy
CHUNK = 1 << 12
tr.save_checkpoint(ckdir, chunk_bytes=CHUNK)
CKPT._to_host = orig

# -- resumed leg: fresh trainer + compile cache, sharded restore ------ #
tr2, loader2 = make()
meta = tr2.restore_checkpoint(ckdir)
assert meta["phase"] == 1, meta
assert isinstance(tr2.state.tokens_seen, int)
loader2.resume(tr2.state.tokens_seen)
tr2.run(loader2)

# re-save over the directory we just resumed from — the launcher's
# save-at-end-of-resumed-run sequence: the save's entry barrier must
# keep process 0 from clobbering the manifest while a slower peer is
# still restoring (regression: gloo DEADLINE + FileNotFoundError)
tr2.save_checkpoint(ckdir)
resave_ok = os.path.isfile(os.path.join(ckdir, "manifest.json"))

# -- cross-process one-writer-per-block save of a data-sharded array -- #
from jax.sharding import NamedSharding, PartitionSpec as P
sharded = jax.make_array_from_process_local_data(
    NamedSharding(mesh, P("data", None)),
    np.arange(12.0, dtype=np.float32).reshape(3, 4) + 100 * pid, (6, 4))
sh_dir = ckdir + "-sharded"
CKPT.save(sh_dir, {"x": sharded}, {"n": np.int32(0)}, step=0,
          tokens_seen=0)
# this process owns exactly ONE of x's two replica-0 blocks — the
# other file can only have been written by the peer process
my_writer_blocks = len(CKPT._writer_blocks(sharded))
p_r, _, _ = CKPT.restore(
    sh_dir, {"x": sharded}, {"n": np.int32(0)},
    shardings=({"x": sharded.sharding},
               {"n": NamedSharding(mesh, P())}))
sharded_ok = all(
    np.array_equal(np.asarray(a.data), np.asarray(b.data))
    for a, b in zip(sharded.addressable_shards,
                    p_r["x"].addressable_shards))

rec = {"pid": pid, "nproc": jax.process_count(),
       "steps_total": steps0 + len(tr2.history),
       "max_transfer": max(transfers), "chunk": CHUNK,
       "n_transfers": len(transfers),
       "sharded_ok": bool(sharded_ok),
       "resave_ok": bool(resave_ok),
       "my_writer_blocks": my_writer_blocks,
       "tokens_meta_int": isinstance(meta["tokens_seen"], int)}

if pid == 0:
    mine = host_params(tr2)
    rec["n_leaves"] = len(mine)
    # resume equivalence, bitwise, against the same-topology baseline
    rec["bitwise"] = all(
        np.array_equal(a, b) for a, b in zip(full_params, mine))
    # cross-topology: within collective-rounding distance of the
    # single-process run
    ref = np.load(refpath)
    rec["ref_max_rel"] = max(
        float((np.abs(ref[k] - v) / (np.abs(ref[k]) + 1e-12)).max())
        for k, v in zip(ref.files, mine))
    man = json.load(open(os.path.join(ckdir, "manifest.json")))
    rec["manifest_leaves"] = len(man["arrays"])
    rec["files_exist"] = all(
        os.path.isfile(os.path.join(ckdir, s["file"]))
        for e in man["arrays"].values() for s in e["shards"])
    man2 = json.load(open(os.path.join(sh_dir, "manifest.json")))
    rec["x_shards"] = len(man2["arrays"]["p:x"]["shards"])
    rec["x_files_exist"] = all(
        os.path.isfile(os.path.join(sh_dir, s["file"]))
        for s in man2["arrays"]["p:x"]["shards"])
print(json.dumps(rec))
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_two_process_ramp_checkpoint_resume_bitwise(run_multiprocess,
                                                    run_subprocess,
                                                    tmp_path):
    ck = str(tmp_path / "ck")
    ref = str(tmp_path / "ref.npz")
    # reference: the identical mesh/workload in ONE process (2 forced
    # host devices) — "the single-process run" of the acceptance
    # criterion
    rec = run_subprocess(SCRIPT, 0, 1, 0, "ref", ck, ref, devices=2,
                         timeout=420)
    assert rec["n_devices"] == 2 and rec["steps"] > 0

    rec = run_multiprocess(SCRIPT, "dist", ck, ref, nprocs=2,
                           devices=1, timeout=540)
    assert rec["nproc"] == 2
    assert rec["bitwise"], rec
    assert rec["ref_max_rel"] <= 1e-4, rec
    assert rec["tokens_meta_int"]
    assert rec["resave_ok"]
    # bounded streaming: no single device→host transfer above the
    # 4 KiB chunk (leaf rows here are far smaller than the chunk)
    assert rec["max_transfer"] <= rec["chunk"], rec
    assert rec["n_transfers"] > rec["manifest_leaves"]
    # manifest complete and every named shard file really on disk
    assert rec["files_exist"]
    # the data-sharded save: each process wrote exactly its one
    # replica-0 block, yet both files exist and reassemble bitwise —
    # the one-writer-per-block protocol worked cross-process
    assert rec["sharded_ok"]
    assert rec["my_writer_blocks"] == 1
    assert rec["x_shards"] == 2 and rec["x_files_exist"], rec
