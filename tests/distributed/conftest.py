"""Shared subprocess runner for the multi-device / multi-process
equivalence harness.

Every test here runs its jax world in a fresh subprocess so the main
pytest process keeps its 1-CPU-device world.  The runner pins
``JAX_PLATFORMS=cpu`` (without the pin, jax probes for a TPU backend
for ~5 minutes per subprocess on this image before falling back) and
forces an N-device host platform via
``--xla_force_host_platform_device_count`` — both set in the
environment *before* the subprocess imports jax, so test scripts need
no device boilerplate.  Scripts report by printing one JSON object as
their last stdout line.
"""
import json
import os
import socket
import subprocess
import sys
import threading
import time

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def _env(devices: int):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}")
    return env


@pytest.fixture
def run_subprocess():
    def run(script: str, *argv, devices: int = 8, timeout: int = 420):
        out = subprocess.run(
            [sys.executable, "-c", script, *map(str, argv)],
            capture_output=True, text=True, env=_env(devices),
            timeout=timeout)
        assert out.returncode == 0, out.stderr[-3000:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    return run


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


@pytest.fixture
def run_multiprocess():
    """Launch ``nprocs`` copies of ``script`` as a true ``jax.distributed``
    process group over a local TCP coordinator.  Each copy receives
    ``(process_id, nprocs, port, *argv)`` as argv and the same pinned
    CPU environment as ``run_subprocess`` (``devices`` forced host
    devices *per process*).  Returns the JSON object printed as the
    last stdout line of process 0."""

    def run(script: str, *argv, nprocs: int = 2, devices: int = 1,
            timeout: int = 540):
        port = _free_port()
        procs = [
            subprocess.Popen(
                [sys.executable, "-c", script, str(pid), str(nprocs),
                 str(port), *map(str, argv)],
                stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                text=True, env=_env(devices))
            for pid in range(nprocs)]
        # drain every process's pipes CONCURRENTLY: a child that fills
        # its 64 KiB pipe while a sibling is being communicate()d would
        # block mid-write, drop out of the collectives, and turn its
        # real traceback into an opaque group-wide timeout
        outs = [None] * nprocs
        threads = [
            threading.Thread(target=lambda i=i, p=p: outs.__setitem__(
                i, p.communicate()), daemon=True)
            for i, p in enumerate(procs)]
        for t in threads:
            t.start()
        deadline = time.monotonic() + timeout
        for t in threads:
            t.join(max(deadline - time.monotonic(), 1))
        if any(t.is_alive() for t in threads):
            for p in procs:
                p.kill()
            for t in threads:
                t.join(10)
            raise subprocess.TimeoutExpired(
                cmd="run_multiprocess", timeout=timeout,
                stderr="; ".join(
                    (o[1] or "")[-500:] for o in outs if o))
        for p, (_, err) in zip(procs, outs):
            assert p.returncode == 0, err[-3000:]
        return json.loads(outs[0][0].strip().splitlines()[-1])

    return run
