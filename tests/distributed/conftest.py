"""Shared subprocess runner for the multi-device / multi-process
equivalence harness.

Every test here runs its jax world in a fresh subprocess so the main
pytest process keeps its 1-CPU-device world.  The runner pins
``JAX_PLATFORMS=cpu`` (without the pin, jax probes for a TPU backend
for ~5 minutes per subprocess on this image before falling back) and
forces an N-device host platform via
``--xla_force_host_platform_device_count`` — both set in the
environment *before* the subprocess imports jax, so test scripts need
no device boilerplate.  Scripts report by printing one JSON object as
their last stdout line.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


@pytest.fixture
def run_subprocess():
    def run(script: str, *argv, devices: int = 8, timeout: int = 420):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        env["JAX_PLATFORMS"] = "cpu"
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={devices}")
        out = subprocess.run(
            [sys.executable, "-c", script, *map(str, argv)],
            capture_output=True, text=True, env=env, timeout=timeout)
        assert out.returncode == 0, out.stderr[-3000:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    return run
