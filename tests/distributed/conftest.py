"""Shared subprocess runner for the multi-device / multi-process
equivalence harness.

Every test here runs its jax world in a fresh subprocess so the main
pytest process keeps its 1-CPU-device world.  The runner pins
``JAX_PLATFORMS=cpu`` (without the pin, jax probes for a TPU backend
for ~5 minutes per subprocess on this image before falling back) and
forces an N-device host platform via
``--xla_force_host_platform_device_count`` — both set in the
environment *before* the subprocess imports jax, so test scripts need
no device boilerplate.  Scripts report by printing one JSON object as
their last stdout line.

Every child is launched in its OWN process group
(``start_new_session=True``) and a test that blows its deadline kills
the whole group with SIGKILL — a hung gloo coordinator (or anything it
forked) fails the suite in minutes instead of wedging the CI job until
the runner-level timeout.
"""
import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def _env(devices: int):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}")
    return env


def _kill_group(p: subprocess.Popen):
    """SIGKILL a child's whole process group (it was started with
    ``start_new_session=True``, so the group is ours to kill); fall
    back to killing just the child if the group is already gone."""
    try:
        os.killpg(os.getpgid(p.pid), signal.SIGKILL)
    except (ProcessLookupError, PermissionError, OSError):
        try:
            p.kill()
        except OSError:
            pass


def _popen(script: str, argv, devices: int) -> subprocess.Popen:
    return subprocess.Popen(
        [sys.executable, "-c", script, *map(str, argv)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        text=True, env=_env(devices), start_new_session=True)


@pytest.fixture
def run_subprocess():
    def run(script: str, *argv, devices: int = 8, timeout: int = 420):
        p = _popen(script, argv, devices)
        try:
            out, err = p.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            _kill_group(p)
            out, err = p.communicate()
            raise subprocess.TimeoutExpired(
                cmd="run_subprocess", timeout=timeout,
                stderr=(err or "")[-2000:])
        assert p.returncode == 0, err[-3000:]
        return json.loads(out.strip().splitlines()[-1])

    return run


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _run_group(script: str, argv, *, nprocs: int, devices: int,
               timeout: int):
    """Launch ``nprocs`` copies of ``script`` as one
    ``jax.distributed`` process group over a local TCP coordinator
    and return ``[(returncode, stdout, stderr)]`` in pid order.  No
    exit-code policy — callers decide (fault-injection tests expect a
    child to die).  On deadline every child's process GROUP is
    SIGKILLed."""
    port = _free_port()
    procs = [_popen(script, (pid, nprocs, port, *argv), devices)
             for pid in range(nprocs)]
    # drain every process's pipes CONCURRENTLY: a child that fills
    # its 64 KiB pipe while a sibling is being communicate()d would
    # block mid-write, drop out of the collectives, and turn its
    # real traceback into an opaque group-wide timeout
    outs = [None] * nprocs
    threads = [
        threading.Thread(target=lambda i=i, p=p: outs.__setitem__(
            i, p.communicate()), daemon=True)
        for i, p in enumerate(procs)]
    for t in threads:
        t.start()
    deadline = time.monotonic() + timeout
    for t in threads:
        t.join(max(deadline - time.monotonic(), 1))
    if any(t.is_alive() for t in threads):
        for p in procs:
            _kill_group(p)
        for t in threads:
            t.join(10)
        raise subprocess.TimeoutExpired(
            cmd="run_group", timeout=timeout,
            stderr="; ".join(
                (o[1] or "")[-500:] for o in outs if o))
    return [(p.returncode, o[0] or "", o[1] or "")
            for p, o in zip(procs, outs)]


@pytest.fixture
def run_multiprocess():
    """Launch ``nprocs`` copies of ``script`` as a true ``jax.distributed``
    process group over a local TCP coordinator.  Each copy receives
    ``(process_id, nprocs, port, *argv)`` as argv and the same pinned
    CPU environment as ``run_subprocess`` (``devices`` forced host
    devices *per process*).  Asserts every process exited 0 and
    returns the JSON object printed as the last stdout line of
    process 0."""

    def run(script: str, *argv, nprocs: int = 2, devices: int = 1,
            timeout: int = 540):
        res = _run_group(script, argv, nprocs=nprocs, devices=devices,
                         timeout=timeout)
        for rc, _, err in res:
            assert rc == 0, err[-3000:]
        return json.loads(res[0][1].strip().splitlines()[-1])

    return run


@pytest.fixture
def run_multiprocess_raw():
    """Like ``run_multiprocess`` but with no exit-code policy: returns
    the raw ``[(returncode, stdout, stderr)]`` in pid order — the
    fault-injection tests kill one child on purpose and inspect the
    survivors."""

    def run(script: str, *argv, nprocs: int = 2, devices: int = 1,
            timeout: int = 540):
        return _run_group(script, argv, nprocs=nprocs, devices=devices,
                          timeout=timeout)

    return run
