"""Per-host data feeding equivalence.

Host level (simulated N processes in-process): concatenating each
process's local shard reproduces the single-process global stream row
for row, for both the per-step and the merged-chunk iterators, and for
a resumed stream.  Mesh level (subprocess, forced 8 CPU devices): the
``jax.make_array_from_process_local_data`` assembly path produces the
same global arrays — and therefore a bitwise-identical training run —
as the plain single-feeder loader.  float32 per the bf16-drift note;
the subprocess pins ``JAX_PLATFORMS=cpu`` via the shared runner.
"""
import numpy as np
import pytest

from repro.core.seesaw import build_plan
from repro.data import MarkovLM, PhaseDataLoader, validate_per_host_plan

SEQ = 32


def _plan(b0=8, steps=40, kind="seesaw"):
    return build_plan(kind=kind, base_lr=1e-3,
                      total_tokens=SEQ * b0 * steps, warmup_frac=0.1,
                      b0=b0, alpha=2.0, n_cuts=2)


def _sim_loaders(plan, n, **kw):
    return [PhaseDataLoader(MarkovLM(128, seed=0), plan, SEQ,
                            per_host=True, process_index=p,
                            process_count=n, **kw) for p in range(n)]


class TestSimulatedPerHost:
    @pytest.mark.parametrize("n", [2, 4])
    def test_step_stream_order_matches_single_process(self, n):
        plan = _plan()
        single = PhaseDataLoader(MarkovLM(128, seed=0), plan, SEQ)
        shards = [iter(l) for l in _sim_loaders(plan, n)]
        count = 0
        for phase, s, gb in single:
            locals_ = [next(it) for it in shards]
            cat = np.concatenate([np.asarray(b["tokens"])
                                  for _, _, b in locals_])
            np.testing.assert_array_equal(np.asarray(gb["tokens"]), cat)
            assert all(p.index == phase.index for p, _, _ in locals_)
            count += 1
        for it in shards:                        # shards exhaust together
            with pytest.raises(StopIteration):
                next(it)
        assert count == plan.total_steps(SEQ)

    def test_chunk_stream_order_matches_single_process(self, n=2, k=16):
        plan = _plan()
        single = PhaseDataLoader(MarkovLM(128, seed=0), plan, SEQ)
        shards = [l.iter_chunks(k) for l in _sim_loaders(plan, n)]
        for phase, gc, m in single.iter_chunks(k):
            locals_ = [next(it) for it in shards]
            assert all(lm == m for _, _, lm in locals_)
            cat = np.concatenate([np.asarray(c["tokens"])
                                  for _, c, _ in locals_], axis=1)
            np.testing.assert_array_equal(np.asarray(gc["tokens"]), cat)

    def test_resumed_shard_continues_global_stream(self):
        plan = _plan()
        single = list(PhaseDataLoader(MarkovLM(128, seed=0), plan, SEQ))
        tok5 = sum(p.batch_size * SEQ for p, _, _ in single[:5])
        shards = [l.resume(tok5) for l in _sim_loaders(plan, 2)]
        first = [next(iter(l)) for l in shards]
        cat = np.concatenate([np.asarray(b["tokens"])
                              for _, _, b in first])
        np.testing.assert_array_equal(
            np.asarray(single[5][2]["tokens"]), cat)

    def test_ramp_validation_rejects_indivisible_batch(self):
        plan = _plan(b0=8)                       # ramp: 8, 16, 32
        with pytest.raises(ValueError, match="does not divide"):
            validate_per_host_plan(plan, process_count=3)
        with pytest.raises(ValueError, match="does not divide"):
            PhaseDataLoader(MarkovLM(128, seed=0), plan, SEQ,
                            per_host=True, process_index=0,
                            process_count=3)

    def test_simulated_process_count_rejects_mesh(self):
        class FakeMesh:
            shape = {"data": 2}
        with pytest.raises(ValueError, match="simulated"):
            PhaseDataLoader(MarkovLM(128, seed=0), _plan(), SEQ,
                            mesh=FakeMesh(), per_host=True,
                            process_index=0, process_count=2)


MESH_SCRIPT = r"""
import json
import jax
import numpy as np
from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.data import MarkovLM, PhaseDataLoader
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import validate_feeding
from repro.train.trainer import Trainer

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                   d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                   d_ff=128, vocab_size=128, max_seq_len=64,
                   rope_theta=1e4)
cfg = RunConfig(
    model=TINY,
    schedule=ScheduleConfig(kind="seesaw", base_lr=1e-3, alpha=2.0,
                            n_cuts=2),
    optimizer=OptimizerConfig(kind="adamw"),
    seq_len=32, global_batch_size=8, total_tokens=32 * 8 * 24,
    remat=False, dtype="float32")
mesh = make_test_mesh(4, 2)

# global arrays assembled from process-local data equal the
# single-feeder arrays (1 real process: the local block is the whole
# batch, but it exercises the make_array_from_process_local_data path)
a = PhaseDataLoader(MarkovLM(128, seed=0), Trainer(cfg).plan, 32,
                    mesh=mesh)
b = PhaseDataLoader(MarkovLM(128, seed=0), Trainer(cfg).plan, 32,
                    mesh=mesh, per_host=True)
arrays_equal = all(
    np.array_equal(np.asarray(x["tokens"]), np.asarray(y["tokens"]))
    and x["tokens"].sharding.is_equivalent_to(y["tokens"].sharding,
                                              x["tokens"].ndim)
    for (_, _, x), (_, _, y) in zip(a, b))

def run(per_host):
    tr = Trainer(cfg, mesh=mesh, fuse_steps=8)
    validate_feeding(tr.plan, mesh)
    loader = PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32,
                             mesh=mesh, per_host=per_host)
    tr.run(loader)
    return tr

plain, perhost = run(False), run(True)
params_equal = all(
    np.array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(jax.device_get(plain.state.params)),
                    jax.tree.leaves(jax.device_get(perhost.state.params))))
print(json.dumps({"arrays_equal": bool(arrays_equal),
                  "params_equal": bool(params_equal),
                  "steps": len(perhost.history),
                  "n_devices": jax.device_count()}))
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_per_host_assembly_matches_single_feeder_on_mesh(run_subprocess):
    rec = run_subprocess(MESH_SCRIPT, devices=8, timeout=420)
    assert rec["n_devices"] == 8
    assert rec["arrays_equal"], rec
    assert rec["params_equal"], rec
    assert rec["steps"] > 0
