"""Async saves and elastic resume, proven end-to-end (PR 6 tentpole).

All modes train the SAME tiny Seesaw workload on the SAME fixed global
``(2, 1)`` data x model mesh — only the process count changes (one
process with 2 forced host devices, or two processes with 1 device
each).

Bitwise claims are only made where bitwise is physically meaningful —
between runs of the SAME topology, or through the checkpoint files
themselves (bytes on disk don't care how many processes read them).
Cross-topology, the in-process XLA all-reduce and the cross-process
gloo all-reduce round differently in the last ulp (measured ~1e-6
relative over this whole run, with per-step loss histories still
identical), so a 2-process run can never be bit-equal to the
single-process run of the same workload; those comparisons assert
exact step/LR/batch histories plus a tight numeric bound instead.

- ``test_async_save_while_training_bitwise``: a 2-process run that
  checkpoints asynchronously every few steps (device snapshot + writer
  thread) must finish with params bitwise-equal to the SAME 2-process
  run saving synchronously — async saves perturb training not at all —
  and its manifest must show BOTH processes wrote blocks (round-robin
  write balancing; params are replicated on this mesh, so under the
  old replica-0-only rule process 0 would have written everything).
- ``test_elastic_resume_2to1_and_1to2``: a checkpoint saved mid-ramp
  (1 step into the batch-16 phase) by a 2-process run resumes on ONE
  process — and one saved by a single process resumes on TWO — with
  ``verify=True`` crc checks and re-derived per-host feed shards.  The
  restored params must equal the saved params BITWISE (the format is
  topology-independent), and the continued run must replay the
  uninterrupted single-process reference exactly step-for-step
  (step/LR/batch identical, loss to float32 resolution) and land
  within collective-rounding distance of its final params.
"""
import pytest

SCRIPT = r"""
import json, os, sys
pid, nproc, port = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
mode, ckdir, refpath = sys.argv[4], sys.argv[5], sys.argv[6]

from repro.launch.train import maybe_init_distributed
if nproc > 1:
    assert maybe_init_distributed(f"127.0.0.1:{port}", nproc, pid)

import jax
import numpy as np
from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.data import MarkovLM, PhaseDataLoader
from repro.train import checkpoint as CKPT
from repro.train.trainer import Trainer

SEQ = 32
TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                   d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                   d_ff=128, vocab_size=128, max_seq_len=64,
                   rope_theta=1e4)
cfg = RunConfig(
    model=TINY,
    schedule=ScheduleConfig(kind="seesaw", base_lr=1e-3, alpha=2.0,
                            n_cuts=2),
    optimizer=OptimizerConfig(kind="adamw"),
    seq_len=SEQ, global_batch_size=8, total_tokens=SEQ * 8 * 24,
    remat=False, dtype="float32")
mesh = jax.make_mesh((2, 1), ("data", "model"))

HIST = refpath + ".hist.json"
ATSAVE = ckdir + "-atsave.npz"


def make(validate=True):
    tr = Trainer(cfg, mesh=mesh, fuse_steps=4)
    loader = PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, SEQ,
                             mesh=mesh, per_host=True,
                             validate=validate)
    return tr, loader


def host_params(tr):
    # params are replicated over the data axis: the local replica
    # block IS the full leaf
    return [np.asarray(x.addressable_shards[0].data)
            for x in jax.tree.leaves(tr.state.params)]


def hist_rows(tr):
    return [[int(r["step"]), float(r["loss"]), float(r["lr"]),
             int(r["batch_size"])] for r in tr.history]


def bitwise_vs_npz(tr, path):
    ref = np.load(path)
    return all(np.array_equal(ref[k], v)
               for k, v in zip(ref.files, host_params(tr)))


def max_rel_vs_npz(tr, path):
    ref = np.load(path)
    worst = 0.0
    for k, v in zip(ref.files, host_params(tr)):
        d = np.abs(ref[k] - v) / (np.abs(ref[k]) + 1e-12)
        worst = max(worst, float(d.max()))
    return worst


def hist_matches(rows, ref_rows):
    # step/LR/batch must replay EXACTLY; loss to float32 resolution
    # (cross-topology collective rounding lives below it)
    if len(rows) != len(ref_rows):
        return False, ["len", len(rows), len(ref_rows)]
    for a, b in zip(rows, ref_rows):
        if (a[0], a[3]) != (b[0], b[3]) or a[2] != b[2]:
            return False, ["row", a, b]
        if abs(a[1] - b[1]) > 1e-5 * max(abs(b[1]), 1e-6):
            return False, ["loss", a, b]
    return True, None


def manifest():
    return json.load(open(os.path.join(ckdir, "manifest.json")))


rec = {"pid": pid, "mode": mode}

if mode == "ref":
    # uninterrupted single-process reference: final params + the full
    # per-step history the elastic resumes must replay
    tr, loader = make()
    tr.run(loader)
    np.savez(refpath, *host_params(tr))
    json.dump(hist_rows(tr), open(HIST, "w"))
    rec.update(steps=len(tr.history), n_devices=jax.device_count())

elif mode == "sync2":
    # 2-process training with periodic SYNC saves — the baseline the
    # async run must match bitwise (same topology, same collectives)
    tr, loader = make()
    tr.run(loader, checkpoint_path=ckdir, save_every=5,
           async_save=False)
    tr.save_checkpoint(ckdir)
    if pid == 0:
        np.savez(refpath, *host_params(tr))
    rec.update(nproc=jax.process_count(), steps=len(tr.history))

elif mode == "async2":
    # the same 2-process run with ASYNC saves at chunk boundaries
    tr, loader = make()
    tr.run(loader, checkpoint_path=ckdir, save_every=5,
           async_save=True)
    tr.close()
    mgr = tr.checkpoint_manager
    async_saves = mgr.saves_committed
    # final committed checkpoint restores (with crc verification) into
    # a fresh trainer on the same topology
    tr.save_checkpoint(ckdir)
    tr3, _ = make()
    meta = tr3.restore_checkpoint(ckdir, verify=True)
    man = manifest()
    writers = sorted({s["writer"] for e in man["arrays"].values()
                      for s in e["shards"]})
    rec.update(
        nproc=jax.process_count(),
        async_saves=async_saves,
        writers=writers,
        restored_step=int(meta["step"]),
        final_step=int(tr.state.step),
        restored_bitwise=bool(all(
            np.array_equal(a, b) for a, b in
            zip(host_params(tr), host_params(tr3)))))
    if pid == 0:
        rec["bitwise"] = bool(bitwise_vs_npz(tr, refpath))

elif mode in ("save1", "save2"):
    # train 1 step INTO the batch-16 phase (genuinely mid-phase: this
    # tiny ramp's phase 1 is only 2 steps long) and save there; stash
    # the exact host params at the save point so the resuming
    # topology can prove the restore is bitwise-faithful
    tr, loader = make()
    mid = tr.plan.steps_per_phase(SEQ)[0] + 1
    tr.run(loader, max_steps=mid)
    assert tr.state.step == mid
    tr.save_checkpoint(ckdir)
    man = manifest()
    rec.update(step=int(tr.state.step),
               save_nproc=man["meta"]["save_process_count"],
               phase=man["meta"]["phase"])
    if pid == 0:
        np.savez(ATSAVE, *host_params(tr))
        ref_rows = json.load(open(HIST))
        ok, why = hist_matches(hist_rows(tr), ref_rows[:mid])
        rec.update(hist_prefix_ok=bool(ok), hist_why=why)

elif mode in ("resume1", "resume2"):
    # elastic resume: process count differs from the saving run's;
    # validation of the remaining ramp happens from the resumed phase
    tr, loader = make(validate=False)
    meta = tr.restore_checkpoint(ckdir, verify=True)
    restored_bitwise = bitwise_vs_npz(tr, ATSAVE)
    loader.resume(tr.state.tokens_seen)
    tr.run(loader)
    rec.update(nproc=jax.process_count(),
               resumed_phase=int(meta["phase"]),
               saved_from=int(meta["save_process_count"]),
               tokens_int=isinstance(tr.state.tokens_seen, int),
               restored_bitwise=bool(restored_bitwise))
    if pid == 0:
        ref_rows = json.load(open(HIST))
        ok, why = hist_matches(hist_rows(tr),
                               ref_rows[len(ref_rows)
                                        - len(tr.history):])
        rec.update(hist_ok=bool(ok), hist_why=why,
                   final_max_rel=max_rel_vs_npz(tr, refpath))

print(json.dumps(rec))
sys.stdout.flush()
os._exit(0)
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_async_save_while_training_bitwise(run_multiprocess, tmp_path):
    ref = str(tmp_path / "sync.npz")
    ck_sync = str(tmp_path / "ck-sync")
    rec = run_multiprocess(SCRIPT, "sync2", ck_sync, ref, nprocs=2,
                           devices=1, timeout=540)
    assert rec["nproc"] == 2 and rec["steps"] > 0

    ck = str(tmp_path / "ck")
    rec = run_multiprocess(SCRIPT, "async2", ck, ref, nprocs=2,
                           devices=1, timeout=540)
    assert rec["nproc"] == 2
    # async saves really happened while training and perturbed nothing:
    # bitwise-identical to the sync-save run of the same topology
    assert rec["async_saves"] >= 2, rec
    assert rec["bitwise"], rec
    # write balancing: on this mesh every block is replicated on both
    # processes, and round-robin spread the writes over both
    assert rec["writers"] == [0, 1], rec
    # the final committed generation restores bitwise (crc-verified)
    assert rec["restored_bitwise"] and \
        rec["restored_step"] == rec["final_step"], rec


@pytest.mark.slow
@pytest.mark.subprocess
def test_elastic_resume_2to1_and_1to2(run_subprocess, run_multiprocess,
                                      tmp_path):
    ref = str(tmp_path / "ref.npz")
    rec = run_subprocess(SCRIPT, 0, 1, 0, "ref", str(tmp_path / "x"),
                         ref, devices=2, timeout=420)
    assert rec["steps"] > 0

    # -- 2 -> 1: two processes save mid-ramp, one process resumes ----- #
    ck = str(tmp_path / "ck21")
    rec = run_multiprocess(SCRIPT, "save2", ck, ref, nprocs=2,
                           devices=1, timeout=540)
    assert rec["save_nproc"] == 2 and rec["phase"] == 1, rec
    assert rec["hist_prefix_ok"], rec
    rec = run_subprocess(SCRIPT, 0, 1, 0, "resume1", ck, ref,
                         devices=2, timeout=420)
    assert rec["saved_from"] == 2 and rec["resumed_phase"] == 1
    assert rec["tokens_int"]
    # the 2-process checkpoint reassembled bitwise on one process
    assert rec["restored_bitwise"], rec
    # and the continued run replays the uninterrupted reference
    assert rec["hist_ok"], rec
    assert rec["final_max_rel"] <= 1e-4, rec

    # -- 1 -> 2: one process saves mid-ramp, two processes resume ----- #
    ck = str(tmp_path / "ck12")
    rec = run_subprocess(SCRIPT, 0, 1, 0, "save1", ck, ref, devices=2,
                         timeout=420)
    assert rec["save_nproc"] == 1 and rec["phase"] == 1, rec
    assert rec["hist_prefix_ok"], rec
    rec = run_multiprocess(SCRIPT, "resume2", ck, ref, nprocs=2,
                           devices=1, timeout=540)
    assert rec["saved_from"] == 1 and rec["resumed_phase"] == 1
    # the single-process checkpoint reassembled bitwise on two
    assert rec["restored_bitwise"], rec
    assert rec["hist_ok"], rec
    assert rec["final_max_rel"] <= 1e-4, rec
