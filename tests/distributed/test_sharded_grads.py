"""Distributed numerical correctness: the pjit-sharded loss/grads on an
8-device host mesh equal the single-device computation — run through
the shared subprocess runner (JAX_PLATFORMS=cpu pinned; without the pin
each subprocess stalls ~5 min probing for a TPU backend)."""
import pytest

pytestmark = [pytest.mark.slow, pytest.mark.subprocess]

SCRIPT = r"""
import json, sys
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import registry as R
from repro.launch.mesh import make_test_mesh

arch = sys.argv[1]
cfg = get_config(arch).reduced()
params = R.init_params(jax.random.PRNGKey(0), cfg)
batch = R.concrete_inputs(cfg, "train", 8, 64)

def loss_of(p, b):
    return R.loss_fn(p, cfg, b, remat=True, dtype=jnp.float32)

# single device reference
(loss_ref, _), grads_ref = jax.value_and_grad(loss_of, has_aux=True)(
    params, batch)

# sharded: params sharded per param_specs, batch over data
mesh = make_test_mesh(2, 2)
pspec = R.param_specs(cfg)
with mesh:
    p_sh = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspec,
        is_leaf=lambda x: isinstance(x, P)))
    b_sh = jax.device_put(batch, NamedSharding(mesh, P("data")))
    f = jax.jit(jax.value_and_grad(loss_of, has_aux=True))
    (loss_sh, _), grads_sh = f(p_sh, b_sh)

err_loss = abs(float(loss_ref) - float(loss_sh))
gerr = max(float(jnp.max(jnp.abs(a - b)))
           for a, b in zip(jax.tree.leaves(grads_ref),
                           jax.tree.leaves(grads_sh)))
print(json.dumps({"loss_err": err_loss, "grad_err": gerr,
                  "loss": float(loss_ref)}))
"""


@pytest.mark.parametrize("arch", ["llama3.2-3b", "granite-moe-1b-a400m",
                                  "mamba2-2.7b"])
def test_sharded_loss_and_grads_match_single_device(arch,
                                                    run_subprocess):
    rec = run_subprocess(SCRIPT, arch, devices=8)
    assert rec["loss_err"] < 1e-4, rec
    assert rec["grad_err"] < 5e-3, rec
