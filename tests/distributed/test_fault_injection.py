"""Kill one process at a randomized point DURING an async save; the
previously committed generation must always restore.

Two real ``jax.distributed`` processes train briefly on the fixed
``(2, 1)`` mesh and commit generation 0 synchronously (process 0 also
writes a host-side reference copy of the params).  Then both request
an ASYNC save of the same state under a different step tag — and the
victim process (chosen by the iteration's seed) SIGKILLs itself after
a seed-chosen number of block writes, mid-stream in its writer
thread.  The survivor must NOT hang: when the victim is a
non-coordinator process, the marker/commit waits are bounded by the
manager's ``commit_timeout`` and surface a ``CheckpointTimeoutError``
at ``finalize()``, with the committed manifest still at generation 0.
When the victim IS process 0, jax's coordination service tears the
survivor down itself (its gRPC stream to the dead coordinator errors
and the runtime aborts) — still bounded, still no commit; the
durability claim is then carried entirely by the independent
verifier.  An independent single-process run
then restores the directory (crc-verified, elastic 2→1) and must get
generation 0's params bitwise and its step tag — proving the murdered
generation-1 save left no trace in what restore sees.

The victim self-kills with SIGKILL — no cleanup, no exit handlers —
which is exactly what a preempted pod looks like to the survivors.
"""
import json
import random

import pytest

SCRIPT = r"""
import json, os, random, signal, sys
pid, nproc, port = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
ckdir, refpath, seed = sys.argv[4], sys.argv[5], int(sys.argv[6])

from repro.launch.train import maybe_init_distributed
assert maybe_init_distributed(f"127.0.0.1:{port}", nproc, pid)

import jax
import numpy as np
from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.data import MarkovLM, PhaseDataLoader
from repro.train import checkpoint as CKPT
from repro.train.trainer import Trainer

SEQ = 32
TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                   d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                   d_ff=128, vocab_size=128, max_seq_len=64,
                   rope_theta=1e4)
cfg = RunConfig(
    model=TINY,
    schedule=ScheduleConfig(kind="seesaw", base_lr=1e-3, alpha=2.0,
                            n_cuts=2),
    optimizer=OptimizerConfig(kind="adamw"),
    seq_len=SEQ, global_batch_size=8, total_tokens=SEQ * 8 * 24,
    remat=False, dtype="float32")
mesh = jax.make_mesh((2, 1), ("data", "model"))
tr = Trainer(cfg, mesh=mesh, fuse_steps=4)
loader = PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, SEQ,
                         mesh=mesh, per_host=True)
tr.run(loader, max_steps=4)

# generation 0: committed synchronously, this is the state that must
# survive; pid 0 also keeps a host copy as the bitwise reference
tr.save_checkpoint(ckdir)
if pid == 0:
    np.savez(refpath, *[np.asarray(x.addressable_shards[0].data)
                        for x in jax.tree.leaves(tr.state.params)])
gen0 = CKPT._committed_generation(ckdir)

# the murder weapon: after `kill_after` block writes, the victim's
# writer thread SIGKILLs the whole process mid-save — both processes
# derive the same (victim, kill_after) from the shared seed
rng = random.Random(seed)
victim = rng.randrange(nproc)
kill_after = 1 + rng.randrange(8)
writes = {"n": 0}
orig = CKPT._stream_write

def lethal(path, data, chunk_bytes):
    writes["n"] += 1
    if pid == victim and writes["n"] >= kill_after:
        os.kill(os.getpid(), signal.SIGKILL)
    return orig(path, data, chunk_bytes)

CKPT._stream_write = lethal

# generation 1 attempt: same arrays, distinct step tag — if it ever
# committed, the verifier below would see step=999 and fail
mgr = tr.engine.make_checkpoint_manager(commit_timeout=8.0)
mgr.request_save(ckdir, tr.state.params, tr.state.opt_state,
                 step=999, tokens_seen=tr.state.tokens_seen)
timeout_error = False
try:
    mgr.finalize()
except CKPT.CheckpointTimeoutError:
    timeout_error = True

rec = {"pid": pid, "victim": victim, "kill_after": kill_after,
       "timeout_error": timeout_error,
       "committed_gen": CKPT._committed_generation(ckdir),
       "gen0": gen0, "my_writes": writes["n"]}
print(json.dumps(rec))
sys.stdout.flush()
# the peer is dead: skip jax.distributed shutdown (it would block on
# the missing process) — this survivor's job is done
os._exit(0)
"""

VERIFY = r"""
import json, os, sys
ckdir, refpath = sys.argv[1], sys.argv[2]
import jax
import numpy as np
from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.train.trainer import Trainer

SEQ = 32
TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                   d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                   d_ff=128, vocab_size=128, max_seq_len=64,
                   rope_theta=1e4)
cfg = RunConfig(
    model=TINY,
    schedule=ScheduleConfig(kind="seesaw", base_lr=1e-3, alpha=2.0,
                            n_cuts=2),
    optimizer=OptimizerConfig(kind="adamw"),
    seq_len=SEQ, global_batch_size=8, total_tokens=SEQ * 8 * 24,
    remat=False, dtype="float32")
mesh = jax.make_mesh((2, 1), ("data", "model"))
tr = Trainer(cfg, mesh=mesh, fuse_steps=4)
meta = tr.restore_checkpoint(ckdir, verify=True)
ref = np.load(refpath)
mine = [np.asarray(x.addressable_shards[0].data)
        for x in jax.tree.leaves(tr.state.params)]
print(json.dumps({
    "step": int(meta["step"]),
    "bitwise": bool(all(np.array_equal(ref[k], v)
                        for k, v in zip(ref.files, mine)))}))
"""


@pytest.mark.slow
@pytest.mark.subprocess
@pytest.mark.parametrize("seed", [0, 1])
def test_kill_during_save_keeps_previous_generation(
        run_multiprocess_raw, run_subprocess, tmp_path, seed):
    ck = str(tmp_path / "ck")
    ref = str(tmp_path / "ref.npz")
    res = run_multiprocess_raw(SCRIPT, ck, ref, seed, nprocs=2,
                               devices=1, timeout=540)
    # the same (victim, kill_after) derivation the script performs
    victim = random.Random(seed).randrange(2)
    # the victim was murdered (SIGKILL -> rc -9) and nobody hung (the
    # harness's deadline would have tripped)
    assert res[victim][0] == -9, res[victim][2][-400:]
    surv_rc, surv_out, surv_err = res[1 - victim]
    if victim == 0:
        # the coordinator died: jax's coordination service tears the
        # survivor down (gRPC stream error -> runtime abort) unless it
        # reached its own bounded timeout first — either way, bounded
        assert surv_rc != -9, surv_err[-400:]
    else:
        # non-coordinator victim: process 0 survives, times out
        # waiting for the dead peer's marker, and reports cleanly
        assert surv_rc == 0, surv_err[-400:]
    if surv_rc == 0:
        rec = json.loads(surv_out.strip().splitlines()[-1])
        assert rec["pid"] != rec["victim"]
        # bounded failure, not a hang: the survivor saw the timeout
        assert rec["timeout_error"], rec
        # and the committed manifest never moved past generation 0
        assert rec["committed_gen"] == rec["gen0"], rec

    # independent restore (fresh single process, elastic 2->1, crc
    # verified): generation 0's params bitwise, generation 1's step
    # tag (999) nowhere to be seen
    rec = run_subprocess(VERIFY, ck, ref, devices=2, timeout=420)
    assert rec["step"] != 999
    assert rec["bitwise"], rec
