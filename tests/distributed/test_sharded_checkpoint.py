"""Sharded checkpoint format on a genuinely model-sharded mesh.

Subprocess (forced 4 CPU devices, ``(2, 2)`` data x model mesh): the
trainer's params/opt state are split along the model axis, so each
matrix leaf has multiple distinct global blocks and every block is
replicated across the data axis.  The save must write exactly one
file per *distinct* block (replicas deduped via ``replica_id == 0``),
restore must reassemble bitwise through
``jax.make_array_from_process_local_data`` with the engine's state
shardings, and a legacy single-file ``.npz`` of the same state must
restore bitwise through the identical sharded assembly path (the
migration criterion).
"""
import pytest

SCRIPT = r"""
import json, os, sys
ckdir = sys.argv[1]
import jax
import numpy as np
from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.data import MarkovLM, PhaseDataLoader
from repro.launch.mesh import make_test_mesh
from repro.train import checkpoint as CKPT
from repro.train.trainer import Trainer

SEQ = 32
TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                   d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                   d_ff=128, vocab_size=128, max_seq_len=64,
                   rope_theta=1e4)
cfg = RunConfig(
    model=TINY,
    schedule=ScheduleConfig(kind="seesaw", base_lr=1e-3, alpha=2.0,
                            n_cuts=2),
    optimizer=OptimizerConfig(kind="adamw"),
    seq_len=SEQ, global_batch_size=8, total_tokens=SEQ * 8 * 12,
    remat=False, dtype="float32")
mesh = make_test_mesh(2, 2)

tr = Trainer(cfg, mesh=mesh, fuse_steps=4)
loader = PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, SEQ, mesh=mesh)
tr.run(loader, max_steps=6)
state = tr.state

CKPT.save(ckdir, state.params, state.opt_state, step=state.step,
          tokens_seen=state.tokens_seen, chunk_bytes=1 << 12)
man = json.load(open(os.path.join(ckdir, "manifest.json")))

# model-sharded leaves produce multiple blocks; files == distinct
# blocks even though every block exists on 2 devices (data replicas)
multi = {k: len(e["shards"]) for k, e in man["arrays"].items()
         if len(e["shards"]) > 1}
gen_dir = os.path.join(ckdir, "arrays", str(man["generation"]))
n_files = len(os.listdir(gen_dir))
n_blocks = sum(len(e["shards"]) for e in man["arrays"].values())

def host_leaves(tree):
    out = []
    for x in jax.tree.leaves(tree):
        shards = sorted(x.addressable_shards, key=lambda s: str(s.index))
        out.append([np.asarray(s.data) for s in shards])
    return out

sh = tr.engine.state_shardings()
p_r, o_r, meta = CKPT.restore(ckdir, state.params, state.opt_state,
                              shardings=sh)
def trees_bitwise(a, b):
    return all(
        all(np.array_equal(x, y) for x, y in zip(xs, ys))
        for xs, ys in zip(host_leaves(a), host_leaves(b)))
restore_ok = trees_bitwise(state.params, p_r) and \
    trees_bitwise(state.opt_state, o_r)
sharding_ok = all(
    x.sharding.is_equivalent_to(y.sharding, x.ndim)
    for x, y in zip(jax.tree.leaves(state.params), jax.tree.leaves(p_r)))

# legacy single-file .npz of the same state -> same sharded assembly
legacy = os.path.join(os.path.dirname(ckdir), "legacy")
CKPT.save_npz(legacy, state.params, state.opt_state, step=state.step,
              tokens_seen=float(state.tokens_seen))
p_l, o_l, meta_l = CKPT.restore(legacy, state.params, state.opt_state,
                                shardings=sh)
legacy_ok = trees_bitwise(state.params, p_l) and \
    trees_bitwise(state.opt_state, o_l)

print(json.dumps({
    "n_devices": jax.device_count(),
    "multi_block_leaves": len(multi),
    "max_blocks": max(multi.values()) if multi else 0,
    "n_files": n_files, "n_blocks": n_blocks,
    "restore_ok": bool(restore_ok), "sharding_ok": bool(sharding_ok),
    "legacy_ok": bool(legacy_ok),
    "meta_tokens_exact": meta["tokens_seen"] == state.tokens_seen
                         and isinstance(meta["tokens_seen"], int),
    "legacy_tokens_float": isinstance(meta_l["tokens_seen"], float)}))
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_model_sharded_save_restore_and_legacy(run_subprocess,
                                               tmp_path):
    rec = run_subprocess(SCRIPT, str(tmp_path / "ck"), devices=4,
                         timeout=420)
    assert rec["n_devices"] == 4
    # the (2,2) mesh really split leaves into multiple global blocks
    assert rec["multi_block_leaves"] > 0
    assert rec["max_blocks"] >= 2
    # one file per distinct block — data-axis replicas deduped
    assert rec["n_files"] == rec["n_blocks"]
    assert rec["restore_ok"] and rec["sharding_ok"], rec
    assert rec["legacy_ok"], rec
    assert rec["meta_tokens_exact"]
    assert rec["legacy_tokens_float"]
