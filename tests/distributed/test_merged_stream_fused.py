"""The tentpole acceptance test: on a forced-8-device CPU mesh, a plan
with adjacent same-batch-size phases runs fused K=16 through the merged
chunk stream with exactly one compiled executable per *distinct* batch
size (no remainder programs), and the fused params are bitwise
identical to the per-phase eager (K=1) reference at equal tokens.

float32 activations throughout: bf16 + AdamW amplify cross-device
reduction-order noise to O(1e-3) in ~20 steps, which would mask a real
divergence (and break a bitwise assertion) — see tests/distributed
conftest for the environment pins.
"""
import pytest

pytestmark = [pytest.mark.slow, pytest.mark.subprocess]

SCRIPT = r"""
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.data import MarkovLM, PhaseDataLoader
from repro.launch.mesh import make_test_mesh
from repro.train.trainer import Trainer

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                   d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                   d_ff=128, vocab_size=128, max_seq_len=64,
                   rope_theta=1e4)

# naive-ramp clamped at 16: batch sizes 8, 16, 16, 16 — the three
# saturated phases merge into one chunk stream, and phase step counts
# are not multiples of K=16, so tail padding is exercised too.
cfg = RunConfig(
    model=TINY,
    schedule=ScheduleConfig(kind="naive-ramp", base_lr=1e-3, alpha=2.0,
                            beta=2.0, n_cuts=3, max_batch_size=16),
    optimizer=OptimizerConfig(kind="adamw"),
    seq_len=32, global_batch_size=8, total_tokens=32 * 8 * 60,
    remat=False, dtype="float32")

mesh = make_test_mesh(4, 2)          # data=4 x model=2 on 8 devices


def run(k):
    tr = Trainer(cfg, mesh=mesh, fuse_steps=k)
    loader = PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32,
                             mesh=mesh)
    tr.run(loader)
    return tr


eager = run(1)
fused = run(16)
e_params = jax.device_get(eager.state.params)
f_params = jax.device_get(fused.state.params)
bitwise = all(np.array_equal(np.asarray(a), np.asarray(b))
              for a, b in zip(jax.tree.leaves(e_params),
                              jax.tree.leaves(f_params)))
hist = all(a["lr"] == b["lr"] and a["phase"] == b["phase"]
           and a["tokens"] == b["tokens"]
           and a["batch_size"] == b["batch_size"]
           for a, b in zip(eager.history, fused.history))
print(json.dumps({
    "bitwise": bitwise,
    "hist_equal": hist and len(eager.history) == len(fused.history),
    "executables": len(fused._step_cache),
    "chunk_ks": sorted({key[2] for key in fused._step_cache}),
    "distinct_batch_sizes": len(set(fused.plan.batch_sizes())),
    "steps": len(fused.history),
    "plan_steps": fused.plan.total_steps(32),
    "tokens": fused.state.tokens_seen,
    "eager_tokens": eager.state.tokens_seen,
    "n_devices": jax.device_count(),
}))
"""


def test_merged_stream_fused_bitwise_vs_eager_on_mesh(run_subprocess):
    rec = run_subprocess(SCRIPT, devices=8, timeout=420)
    assert rec["n_devices"] == 8
    assert rec["bitwise"], rec
    assert rec["hist_equal"], rec
    # exactly one fused executable per distinct batch size, all at K=16
    assert rec["executables"] == rec["distinct_batch_sizes"] == 2, rec
    assert rec["chunk_ks"] == [16], rec
    # carry conservation at equal tokens
    assert rec["steps"] == rec["plan_steps"]
    assert rec["tokens"] == rec["eager_tokens"]
