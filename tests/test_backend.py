"""Kernel-backend registry: model-level equivalence and training parity.

The registry (``repro.kernels.backend``) routes attention / RMSNorm /
SSD through a selectable backend.  These tests pin the contract the
docs (docs/kernels.md) promise:

- a model forward under ``pallas_interpret`` matches the ``xla``
  backend to f32 tolerance for both the dense and ssm families;
- a reduced-config *training run* across a seesaw batch-size ramp
  boundary matches between backends, and the engine still compiles
  exactly one fused executable per distinct batch size (the kernel
  routing must not break the PR-4 compile-cache invariant).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig, SSMConfig)
from repro.data import MarkovLM, PhaseDataLoader
from repro.models import registry as R
from repro.train.trainer import Trainer

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                   n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                   vocab_size=128, max_seq_len=64, rope_theta=1e4)
TINY_SSM = ModelConfig(name="tiny-ssm", arch_type="ssm", n_layers=2,
                       d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                       d_ff=128, vocab_size=128, max_seq_len=64,
                       rope_theta=1e4,
                       ssm=SSMConfig(d_state=16, d_conv=4, expand=2,
                                     head_dim=32, chunk_size=32))


def _with_backend(cfg: ModelConfig, backend: str) -> ModelConfig:
    return dataclasses.replace(cfg, kernel_backend=backend)


class TestModelForwardEquivalence:
    @pytest.mark.parametrize("base", [TINY, TINY_SSM],
                             ids=["dense", "ssm"])
    def test_loss_and_grads_match_xla(self, base):
        params = R.init_params(jax.random.PRNGKey(0), base)
        batch = R.concrete_inputs(base, "train", 2, 64)

        def run(backend):
            cfg = _with_backend(base, backend)
            return jax.value_and_grad(
                lambda p: R.loss_fn(p, cfg, batch, remat=False,
                                    dtype=jnp.float32)[0]
            )(params)

        (loss_x, grads_x) = run("xla")
        (loss_p, grads_p) = run("pallas_interpret")
        # tolerance policy (docs/kernels.md): f32 activations — the
        # kernels only reorder f32 accumulations.  (Under the default
        # bf16 activations the cross-backend gap is bf16 rounding,
        # ~1e-2 relative, which would mask real bugs here.)
        assert abs(float(loss_x) - float(loss_p)) < 1e-5
        for gx, gp in zip(jax.tree.leaves(grads_x),
                          jax.tree.leaves(grads_p)):
            np.testing.assert_allclose(np.asarray(gx), np.asarray(gp),
                                       atol=1e-5, rtol=1e-4)

    def test_hidden_states_match_xla(self):
        params = R.init_params(jax.random.PRNGKey(0), TINY)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 48),
                                    0, TINY.vocab_size)
        hx, _ = R.forward_hidden(params, _with_backend(TINY, "xla"),
                                 tokens, dtype=jnp.float32)
        hp, _ = R.forward_hidden(params, _with_backend(
            TINY, "pallas_interpret"), tokens, dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(hx), np.asarray(hp),
                                   atol=1e-5, rtol=1e-5)

    def test_bad_backend_fails_fast(self):
        with pytest.raises(ValueError, match="kernel backend"):
            R.init_params(jax.random.PRNGKey(0),
                          _with_backend(TINY, "cuda"))


class TestRunConfigOverride:
    def test_run_level_override_folds_into_model(self):
        cfg = RunConfig(model=TINY,
                        schedule=ScheduleConfig(kind="cosine",
                                                base_lr=1e-3),
                        optimizer=OptimizerConfig(),
                        seq_len=32, global_batch_size=4,
                        total_tokens=32 * 4 * 4,
                        kernel_backend="pallas_interpret")
        assert cfg.resolved_model().kernel_backend == "pallas_interpret"
        assert cfg.model.kernel_backend == "xla"   # untouched

    def test_no_override_is_identity(self):
        cfg = RunConfig(model=TINY,
                        schedule=ScheduleConfig(kind="cosine",
                                                base_lr=1e-3),
                        optimizer=OptimizerConfig(),
                        seq_len=32, global_batch_size=4,
                        total_tokens=32 * 4 * 4)
        assert cfg.resolved_model() is cfg.model


@pytest.mark.slow
class TestRampTrainingParity:
    """Acceptance criterion: reduced-config training with
    ``--kernel-backend pallas_interpret`` matches ``xla`` across a
    batch-size ramp boundary while preserving one-fused-executable-
    per-distinct-batch-size."""

    def _train(self, backend):
        b0, steps, seq = 4, 12, 32
        cfg = RunConfig(
            model=TINY,
            schedule=ScheduleConfig(kind="seesaw", base_lr=1e-3,
                                    alpha=2.0, n_cuts=2),
            optimizer=OptimizerConfig(),
            seq_len=seq, global_batch_size=b0,
            total_tokens=seq * b0 * steps, dtype="float32",
            remat=False, kernel_backend=backend)
        tr = Trainer(cfg, fuse_steps=4)
        tr.run(PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, seq))
        return tr

    def test_backends_match_across_ramp(self):
        tr_x = self._train(None)                  # xla default
        tr_p = self._train("pallas_interpret")
        # the seesaw plan actually ramps (≥ 2 distinct batch sizes), so
        # the trajectory crosses at least one chunk-shape boundary
        distinct_b = set(tr_x.plan.batch_sizes())
        assert len(distinct_b) >= 2
        lx = [h["loss"] for h in tr_x.history]
        lp = [h["loss"] for h in tr_p.history]
        assert len(lx) == len(lp) > 0
        assert max(abs(a - b) for a, b in zip(lx, lp)) < 5e-4
        dp = max(float(jnp.abs(a - b).max()) for a, b in
                 zip(jax.tree.leaves(tr_x.state.params),
                     jax.tree.leaves(tr_p.state.params)))
        assert dp < 5e-4
        # kernel routing must not fragment the compile cache
        for tr in (tr_x, tr_p):
            assert len(tr.engine._cache) == len(distinct_b)
