"""Numerical verification of the paper's Section 5 results using the
exact bias/variance recursions (no sampling noise)."""
import math

import numpy as np
import pytest

from repro.core import theory as T

LAM = T.power_law_spectrum(80, a=1.0)
SIGMA2 = 1.0
ETA0 = T.stability_eta(LAM)
B0 = 8
SAMPLES = [4096] * 6


@pytest.fixture(scope="module")
def warm_m():
    return T.warm_start(LAM, SIGMA2, ETA0, B0, 2000)


class TestTheorem1:
    def test_equivalence_matched_products(self, warm_m):
        """α₁β₁ = α₂β₂ ⇒ risks within a constant factor (we see ≈1)."""
        r = T.theorem1_risk_ratio(LAM, SIGMA2, eta0=ETA0, b0=B0,
                                  alpha1=4.0, beta1=1.0, alpha2=2.0,
                                  beta2=2.0, samples_per_phase=SAMPLES,
                                  m_start=warm_m)
        assert 0.5 < r < 2.0

    def test_equivalence_three_way(self, warm_m):
        """(8,1), (4,2), (2,4) all share αβ=8."""
        r1 = T.theorem1_risk_ratio(LAM, SIGMA2, eta0=ETA0, b0=B0,
                                   alpha1=8.0, beta1=1.0, alpha2=4.0,
                                   beta2=2.0, samples_per_phase=SAMPLES,
                                   m_start=warm_m)
        r2 = T.theorem1_risk_ratio(LAM, SIGMA2, eta0=ETA0, b0=B0,
                                   alpha1=8.0, beta1=1.0, alpha2=2.0,
                                   beta2=4.0, samples_per_phase=SAMPLES,
                                   m_start=warm_m)
        assert 0.5 < r1 < 2.0 and 0.5 < r2 < 2.0

    def test_mismatched_products_diverge_in_risk(self, warm_m):
        """αβ mismatched ⇒ ratio drifts from 1 with more phases."""
        short = T.theorem1_risk_ratio(LAM, SIGMA2, eta0=ETA0, b0=B0,
                                      alpha1=4.0, beta1=1.0, alpha2=1.2,
                                      beta2=1.0,
                                      samples_per_phase=[4096] * 2,
                                      m_start=warm_m)
        long = T.theorem1_risk_ratio(LAM, SIGMA2, eta0=ETA0, b0=B0,
                                     alpha1=4.0, beta1=1.0, alpha2=1.2,
                                     beta2=1.0,
                                     samples_per_phase=[4096] * 8,
                                     m_start=warm_m)
        assert abs(math.log(long)) > abs(math.log(short))


class TestCorollary1:
    def test_nsgd_equivalence_matched_alpha_sqrt_beta(self, warm_m):
        """Corollary 1: α√β matched ⇒ equivalent NSGD risk.
        (2,1) vs (√2,2): 2·1 = √2·√2."""
        eta_n = ETA0 * math.sqrt(SIGMA2 * np.sum(LAM) / B0)
        r = T.corollary1_risk_ratio(LAM, SIGMA2, eta0=eta_n, b0=B0,
                                    alpha1=2.0, beta1=1.0,
                                    alpha2=math.sqrt(2.0), beta2=2.0,
                                    samples_per_phase=SAMPLES,
                                    m_start=warm_m)
        assert 0.5 < r < 2.0

    def test_nsgd_equivalence_exact_denominator(self, warm_m):
        """Same but with the exact E‖g‖² denominator (Assumption 2 not
        imposed) — still equivalent at small batch."""
        eta_n = ETA0 * math.sqrt(SIGMA2 * np.sum(LAM) / B0)
        r = T.corollary1_risk_ratio(LAM, SIGMA2, eta0=eta_n, b0=B0,
                                    alpha1=2.0, beta1=1.0,
                                    alpha2=math.sqrt(2.0), beta2=2.0,
                                    samples_per_phase=SAMPLES,
                                    m_start=warm_m,
                                    variance_dominated=False)
        assert 0.4 < r < 2.5

    def test_sgd_rule_wrong_for_nsgd(self, warm_m):
        """Using the SGD rule (αβ const) under NSGD drifts more than the
        Corollary-1 rule (α√β const) — the core of why Seesaw uses √α."""
        eta_n = ETA0 * math.sqrt(SIGMA2 * np.sum(LAM) / B0)
        good = T.corollary1_risk_ratio(LAM, SIGMA2, eta0=eta_n, b0=B0,
                                       alpha1=2.0, beta1=1.0,
                                       alpha2=math.sqrt(2.0), beta2=2.0,
                                       samples_per_phase=SAMPLES,
                                       m_start=warm_m)
        bad = T.corollary1_risk_ratio(LAM, SIGMA2, eta0=eta_n, b0=B0,
                                      alpha1=2.0, beta1=1.0,
                                      alpha2=1.0, beta2=2.0,
                                      samples_per_phase=SAMPLES,
                                      m_start=warm_m)
        assert abs(math.log(good)) < abs(math.log(bad))


class TestLemma4:
    def test_alpha_below_sqrt_beta_diverges(self, warm_m):
        """α < √β: effective LR grows (√β/α)^k per phase ⇒ eventual
        divergence of NSGD."""
        eta_n = 0.5 * math.sqrt(SIGMA2 * np.sum(LAM) / B0) \
            * T.stability_eta(LAM) / T.stability_eta(LAM)  # O(1) base
        eta_n = 20 * ETA0 * math.sqrt(SIGMA2 * np.sum(LAM) / B0)
        ph = T.phase_schedule(eta_n, B0, alpha=1.0, beta=4.0,
                              samples_per_phase=[2048] * 14)
        risks, _, _ = T.run_schedule(LAM, SIGMA2, ph, m0=warm_m,
                                     normalized=True,
                                     assume_variance_dominated=True)
        assert (not np.isfinite(risks[-1])) or risks[-1] > 1e3 * risks[0]

    def test_effective_lr_ratio(self):
        from repro.core.seesaw import effective_lr_ratio
        assert effective_lr_ratio(1.0, 4.0, 3) == pytest.approx(8.0)
        assert effective_lr_ratio(math.sqrt(2), 2.0, 5) == pytest.approx(1.0)


class TestNSGDReduction:
    def test_variance_dominated_matches_rescaled_sgd(self, warm_m):
        """Under Assumption 2, NSGD ≡ SGD with η̃ = η√B/(σ√TrH) (eq. 7)."""
        trH = float(np.sum(LAM))
        eta = 0.3
        eta_sgd = eta * math.sqrt(B0) / math.sqrt(SIGMA2 * trH)
        ph_n = [T.TheoryPhase(eta, B0, 500)]
        ph_s = [T.TheoryPhase(eta_sgd, B0, 500)]
        rn, _, mn = T.run_schedule(LAM, SIGMA2, ph_n, m0=warm_m,
                                   normalized=True,
                                   assume_variance_dominated=True)
        rs, _, ms = T.run_schedule(LAM, SIGMA2, ph_s, m0=warm_m)
        np.testing.assert_allclose(mn, ms, rtol=1e-10)

    def test_grad_norm_decomposition(self, warm_m):
        """E‖g‖² ≈ σ²TrH/B once bias is burned down (Assumption 2)."""
        trH = float(np.sum(LAM))
        e = np.zeros_like(LAM)
        exact = T.effective_grad_norm_sq(warm_m, e, LAM, B0, SIGMA2)
        approx = SIGMA2 * trH / B0
        assert exact == pytest.approx(approx, rel=0.25)

    def test_variance_term_shrinks_with_batch(self, warm_m):
        e = np.zeros_like(LAM)
        g8 = T.effective_grad_norm_sq(warm_m, e, LAM, 8, SIGMA2)
        g64 = T.effective_grad_norm_sq(warm_m, e, LAM, 64, SIGMA2)
        assert g8 / g64 == pytest.approx(8.0, rel=0.3)
