"""CheckpointManager edge cases on a single process.

The async writer must behave like the sync save observably: same
on-disk format (manifest with crc32 + writer fields), same restore.
The edge cases that make it safe in a real step loop: rapid-fire
requests coalesce to first + newest, a writer-thread exception is
re-raised at the next interaction instead of vanishing, GC never
deletes the only committed generation, and the snapshot is isolated
from donation (deleting the live buffers after ``request_save`` must
not corrupt the save).
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.train import checkpoint as CKPT


def _state(seed=0, n=6):
    rng = np.random.default_rng(seed)
    import jax.numpy as jnp
    params = {"w": jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32)),
              "b": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}
    opt = {"mu": {"w": jnp.zeros((n, 4), np.float32),
                  "b": jnp.ones((4,), np.float32)},
           "count": jnp.int32(3)}
    return params, opt


def _manifest(base):
    with open(os.path.join(base, "manifest.json")) as f:
        return json.load(f)


def test_async_save_commits_and_matches_sync(tmp_path):
    params, opt = _state()
    mgr = CKPT.CheckpointManager()
    a = str(tmp_path / "async")
    mgr.request_save(a, params, opt, step=7, tokens_seen=123, block=True)
    mgr.finalize()

    s = str(tmp_path / "sync")
    CKPT.save(s, params, opt, step=7, tokens_seen=123)

    ma, ms = _manifest(a), _manifest(s)
    assert ma["meta"]["step"] == ms["meta"]["step"] == 7
    assert ma["meta"]["tokens_seen"] == 123
    assert ma["arrays"].keys() == ms["arrays"].keys()
    for key, ea in ma["arrays"].items():
        for sh_a, sh_s in zip(ea["shards"], ms["arrays"][key]["shards"]):
            # identical content => identical checksums; single process
            # => every writer is 0, recorded in both manifests
            assert sh_a["crc32"] == sh_s["crc32"]
            assert sh_a["writer"] == sh_s["writer"] == 0

    pa, oa, meta = CKPT.restore(a, params, opt, verify=True)
    for k in params:
        assert np.array_equal(np.asarray(pa[k]), np.asarray(params[k]))
    assert np.array_equal(np.asarray(oa["mu"]["b"]),
                          np.asarray(opt["mu"]["b"]))
    assert meta["step"] == 7


def test_overlapping_requests_coalesce_to_newest(tmp_path, monkeypatch):
    """Three rapid requests while the writer is gated: the first starts
    immediately, the middle one is superseded, and after release the
    committed checkpoint is the NEWEST request — exactly 2 saves ran."""
    params, opt = _state()
    path = str(tmp_path / "ck")
    gate = threading.Event()
    orig = CKPT._stream_write

    def gated(p, data, chunk_bytes):
        gate.wait(timeout=30)
        return orig(p, data, chunk_bytes)

    monkeypatch.setattr(CKPT, "_stream_write", gated)
    mgr = CKPT.CheckpointManager()
    mgr.request_save(path, params, opt, step=1, tokens_seen=10)
    mgr.request_save(path, params, opt, step=2, tokens_seen=20)
    mgr.request_save(path, params, opt, step=3, tokens_seen=30)
    gate.set()
    mgr.finalize()
    assert mgr.saves_started == 2          # first + coalesced newest
    assert mgr.saves_committed == 2
    man = _manifest(path)
    assert man["meta"]["step"] == 3 and man["meta"]["tokens_seen"] == 30
    # generations stayed sequential; only the last one is on disk
    assert os.listdir(os.path.join(path, "arrays")) == \
        [str(man["generation"])]


def test_writer_error_reraised_then_cleared(tmp_path, monkeypatch):
    params, opt = _state()
    path = str(tmp_path / "ck")
    boom = RuntimeError("disk on fire")

    def failing(p, data, chunk_bytes):
        raise boom

    monkeypatch.setattr(CKPT, "_stream_write", failing)
    mgr = CKPT.CheckpointManager()
    mgr.request_save(path, params, opt, step=1, tokens_seen=10)
    mgr.wait()
    with pytest.raises(RuntimeError, match="disk on fire"):
        mgr.check()
    # the error was surfaced once, not latched forever
    mgr.check()
    monkeypatch.undo()
    mgr.request_save(path, params, opt, step=2, tokens_seen=20,
                     block=True)
    mgr.finalize()
    assert _manifest(path)["meta"]["step"] == 2


def test_writer_error_surfaces_on_finalize(tmp_path, monkeypatch):
    params, opt = _state()

    def failing(p, data, chunk_bytes):
        raise OSError("enospc")

    monkeypatch.setattr(CKPT, "_stream_write", failing)
    mgr = CKPT.CheckpointManager()
    mgr.request_save(str(tmp_path / "ck"), params, opt, step=1,
                     tokens_seen=10)
    with pytest.raises(OSError, match="enospc"):
        mgr.finalize()


def test_gc_never_deletes_last_committed_generation(tmp_path,
                                                    monkeypatch):
    """A save that fails after streaming some shards must leave the
    previously committed generation on disk and restorable — and the
    next successful save GCs only the committed predecessor."""
    params, opt = _state()
    path = str(tmp_path / "ck")
    mgr = CKPT.CheckpointManager()
    mgr.request_save(path, params, opt, step=1, tokens_seen=10,
                     block=True)
    gen0 = _manifest(path)["generation"]

    calls = {"n": 0}
    orig = CKPT._stream_write

    def fail_late(p, data, chunk_bytes):
        calls["n"] += 1
        if calls["n"] >= 2:
            raise OSError("died mid-save")
        return orig(p, data, chunk_bytes)

    monkeypatch.setattr(CKPT, "_stream_write", fail_late)
    mgr.request_save(path, params, opt, step=2, tokens_seen=20)
    mgr.wait()
    with pytest.raises(OSError, match="died mid-save"):
        mgr.check()
    monkeypatch.undo()
    # committed generation survived the failed save, still restorable
    assert _manifest(path)["generation"] == gen0
    assert os.path.isdir(os.path.join(path, "arrays", str(gen0)))
    _, _, meta = CKPT.restore(path, params, opt, verify=True)
    assert meta["step"] == 1
    # and the next good save commits gen+1, GCing exactly gen0
    mgr.request_save(path, params, opt, step=3, tokens_seen=30,
                     block=True)
    mgr.finalize()
    man = _manifest(path)
    assert man["generation"] == gen0 + 1 and man["meta"]["step"] == 3
    assert os.listdir(os.path.join(path, "arrays")) == \
        [str(gen0 + 1)]


def test_snapshot_isolated_from_buffer_donation(tmp_path, monkeypatch):
    """The request-time snapshot must hold its own device buffers: the
    step loop's donated next step may invalidate the live state while
    the writer is still streaming.  Simulated by gating the writer and
    deleting the original arrays mid-save."""
    import jax
    params, opt = _state()
    path = str(tmp_path / "ck")
    host = {k: np.asarray(v) for k, v in params.items()}
    gate = threading.Event()
    orig = CKPT._stream_write

    def gated(p, data, chunk_bytes):
        gate.wait(timeout=30)
        return orig(p, data, chunk_bytes)

    monkeypatch.setattr(CKPT, "_stream_write", gated)
    mgr = CKPT.CheckpointManager()
    mgr.request_save(path, params, opt, step=1, tokens_seen=10)
    for leaf in jax.tree.leaves((params, opt)):
        leaf.delete()                     # what donation does
    gate.set()
    mgr.finalize()
    t_params = {k: np.zeros_like(v) for k, v in host.items()}
    t_opt = {"mu": {"w": np.zeros((6, 4), np.float32),
                    "b": np.zeros((4,), np.float32)},
             "count": np.int32(0)}
    p_r, _, _ = CKPT.restore(path, t_params, t_opt, verify=True)
    for k, v in host.items():
        assert np.array_equal(np.asarray(p_r[k]), v)
