"""Unit tests for the recurrent cores: chunked RG-LRU scan and chunked
SSD vs their sequential definitions, including chunk-boundary cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import ssd_chunked, ssd_step
from repro.models.rglru import rglru_scan, rglru_step

KEY = jax.random.PRNGKey(11)


class TestRGLRUChunked:
    @pytest.mark.parametrize("S,chunk", [(16, 16), (64, 16), (77, 16),
                                         (33, 512)])
    def test_matches_sequential(self, S, chunk):
        B, W = 2, 8
        ks = jax.random.split(KEY, 4)
        y = jax.random.normal(ks[0], (B, S, W))
        r = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S, W)))
        i = jax.nn.sigmoid(jax.random.normal(ks[2], (B, S, W)))
        lam = jax.random.normal(ks[3], (W,)) * 0.2
        hs, hl = rglru_scan(y, r, i, lam, chunk=chunk)
        h = jnp.zeros((B, W))
        outs = []
        for t in range(S):
            _, h = rglru_step(h, y[:, t], r[:, t], i[:, t], lam)
            outs.append(h)
        want = jnp.stack(outs, 1)
        np.testing.assert_allclose(np.asarray(hs), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(hl), np.asarray(want[:, -1]),
                                   atol=1e-5, rtol=1e-5)

    def test_initial_state_carried(self):
        B, S, W = 1, 20, 4
        ks = jax.random.split(KEY, 5)
        y = jax.random.normal(ks[0], (B, S, W))
        r = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S, W)))
        i = jax.nn.sigmoid(jax.random.normal(ks[2], (B, S, W)))
        lam = jax.random.normal(ks[3], (W,)) * 0.2
        h0 = jax.random.normal(ks[4], (B, W))
        # streaming in two halves == one shot
        hs_a, hl_a = rglru_scan(y[:, :10], r[:, :10], i[:, :10], lam,
                                h0=h0, chunk=4)
        hs_b, hl_b = rglru_scan(y[:, 10:], r[:, 10:], i[:, 10:], lam,
                                h0=hl_a, chunk=4)
        hs_full, hl_full = rglru_scan(y, r, i, lam, h0=h0, chunk=8)
        np.testing.assert_allclose(
            np.concatenate([np.asarray(hs_a), np.asarray(hs_b)], 1),
            np.asarray(hs_full), atol=1e-5, rtol=1e-5)

    def test_forgetting_bound(self):
        """|h| stays bounded: a ∈ (0,1) and √(1−a²) gating make the map
        a contraction for bounded inputs."""
        B, S, W = 1, 200, 4
        ks = jax.random.split(KEY, 4)
        y = 10 * jax.random.normal(ks[0], (B, S, W))
        r = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S, W)))
        i = jax.nn.sigmoid(jax.random.normal(ks[2], (B, S, W)))
        lam = jnp.ones((W,))
        hs, _ = rglru_scan(y, r, i, lam)
        assert bool(jnp.isfinite(hs).all())
        assert float(jnp.max(jnp.abs(hs))) < 100.0


class TestSSDStreaming:
    def test_chunked_state_feeds_step(self):
        """ssd_chunked final state + ssd_step continues the sequence
        identically to running ssd_chunked over the longer sequence."""
        B, S, H, P, N = 1, 32, 2, 8, 4
        ks = jax.random.split(KEY, 5)
        xh = jax.random.normal(ks[0], (B, S + 1, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S + 1, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S + 1, N))
        Cm = jax.random.normal(ks[4], (B, S + 1, N))
        D = jnp.ones((H,))
        y_full, _ = ssd_chunked(xh, dt, A, Bm, Cm, D, chunk=8)
        _, h = ssd_chunked(xh[:, :S], dt[:, :S], A, Bm[:, :S],
                           Cm[:, :S], D, chunk=8)
        # h: (B,H,P,N); ssd_step expects the same layout
        h2, y_last = ssd_step(h, xh[:, S], dt[:, S], A, Bm[:, S],
                              Cm[:, S], D)
        np.testing.assert_allclose(np.asarray(y_last),
                                   np.asarray(y_full[:, S]),
                                   atol=1e-4, rtol=1e-4)
