"""Per-kernel shape/dtype sweeps, assert_allclose vs the ref.py oracles
(Pallas executed with interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import backend as KB
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd import ssd_full

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,Hkv,S,hd", [
        (2, 4, 2, 256, 64),     # GQA
        (1, 8, 8, 128, 128),    # MHA, MXU-square blocks
        (2, 4, 1, 512, 32),     # MQA
        (1, 2, 2, 384, 64),     # non-pow2 sequence
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_oracle(self, B, H, Hkv, S, hd, causal):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, S, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, S, hd), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, interpret=True)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 4, 256, 64)).astype(dtype)
        k = jax.random.normal(ks[1], (1, 2, 256, 64)).astype(dtype)
        v = jax.random.normal(ks[2], (1, 2, 256, 64)).astype(dtype)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        want = ref.attention_ref(q, k, v, causal=True)
        assert out.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))

    def test_block_shape_independence(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 512, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 512, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 512, 64), jnp.float32)
        o1 = flash_attention(q, k, v, block_q=128, block_k=128,
                             interpret=True)
        o2 = flash_attention(q, k, v, block_q=64, block_k=256,
                             interpret=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=1e-5, rtol=1e-5)


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(4, 128), (2, 33, 256), (512,),
                                       (3, 5, 7, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, shape, dtype):
        x = jax.random.normal(KEY, shape).astype(dtype)
        s = (jax.random.normal(jax.random.PRNGKey(1), (shape[-1],))
             * 0.1).astype(dtype)
        out = rmsnorm(x, s, interpret=True)
        want = ref.rmsnorm_ref(x, s)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))

    def test_row_blocking_boundary(self):
        x = jax.random.normal(KEY, (130, 64))   # not a block multiple
        s = jnp.zeros((64,))
        out = rmsnorm(x, s, block_rows=64, interpret=True)
        want = ref.rmsnorm_ref(x, s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=1e-6)


class TestSSD:
    @pytest.mark.parametrize("B,S,H,P,N,Q", [
        (2, 96, 4, 32, 16, 32),
        (1, 128, 2, 64, 32, 64),
        (2, 100, 3, 16, 8, 32),   # padding path
    ])
    def test_matches_naive_recurrence(self, B, S, H, P, N, Q):
        ks = jax.random.split(KEY, 5)
        xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, N))
        Cm = jax.random.normal(ks[4], (B, S, N))
        D = jnp.ones((H,)) * 0.5
        y, h = ssd_full(xh, dt, A, Bm, Cm, D, chunk=Q, interpret=True)
        yr, hr = ref.ssd_ref(xh, dt, A, Bm, Cm, D)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   atol=5e-4, rtol=5e-4)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                                   atol=5e-4, rtol=5e-4)

    def test_model_path_matches_kernel(self):
        """models.mamba2.ssd_chunked (XLA path) ≡ kernels.ssd (Pallas)."""
        from repro.models.mamba2 import ssd_chunked
        ks = jax.random.split(KEY, 5)
        B, S, H, P, N = 2, 64, 2, 16, 8
        xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, N))
        Cm = jax.random.normal(ks[4], (B, S, N))
        D = jnp.zeros((H,))
        y1, h1 = ssd_chunked(xh, dt, A, Bm, Cm, D, chunk=16)
        y2, h2 = ssd_full(xh, dt, A, Bm, Cm, D, chunk=16, interpret=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------- #
# training parity: custom-VJP backwards vs the differentiable oracles
# --------------------------------------------------------------------- #

def _grads(fn, *args):
    """Gradients of a scalarized sum-loss wrt every argument."""
    def loss(*a):
        out = fn(*a)
        leaves = jax.tree.leaves(out)
        return sum(jnp.sum(x.astype(jnp.float32)) for x in leaves)
    return jax.grad(loss, argnums=tuple(range(len(args))))(*args)


def _assert_grads_close(got, want, atol, rtol=0.0):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=atol, rtol=rtol)


class TestFlashAttentionGrads:
    @pytest.mark.parametrize("B,H,Hkv,S,hd,bq,bk", [
        (1, 4, 4, 128, 64, 64, 64),    # MHA
        (1, 4, 2, 128, 64, 64, 64),    # GQA 2:1 head ratio
        (2, 4, 1, 128, 32, 64, 64),    # MQA 4:1 head ratio
        (1, 2, 2, 256, 64, 64, 128),   # mixed blocks, 4 q / 2 k
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_oracle_grads(self, B, H, Hkv, S, hd, bq, bk,
                                  causal):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, S, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, S, hd), jnp.float32)
        got = _grads(lambda q, k, v: flash_attention(
            q, k, v, causal=causal, block_q=bq, block_k=bk,
            interpret=True), q, k, v)
        want = _grads(lambda q, k, v: ref.attention_ref(
            q, k, v, causal=causal), q, k, v)
        _assert_grads_close(got, want, atol=2e-5)

    def test_grads_under_jit(self):
        """The lru-cached custom_vjp must be jit-stable (no retrace
        surprises, identical values inside jit)."""
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 128, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 128, 32), jnp.float32)
        fn = lambda q, k, v: flash_attention(q, k, v, interpret=True)
        eager = _grads(fn, q, k, v)
        jitted = jax.jit(lambda q, k, v: _grads(fn, q, k, v))(q, k, v)
        _assert_grads_close(jitted, eager, atol=1e-6)


class TestFlashAttentionValidation:
    def test_block_not_dividing_seq_raises(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 100, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 100, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 100, 32), jnp.float32)
        with pytest.raises(ValueError, match="block_"):
            flash_attention(q, k, v, block_q=64, block_k=64,
                            interpret=True)

    def test_head_ratio_validated(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 3, 128, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 128, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 128, 32), jnp.float32)
        with pytest.raises(ValueError, match="head"):
            flash_attention(q, k, v, interpret=True)

    def test_backend_pads_ragged_causal_tail(self):
        """backend.attention (models layout) handles S not a multiple
        of the block by zero-padding keys past the causal horizon."""
        ks = jax.random.split(KEY, 3)
        B, S, H, hd = 1, 100, 2, 32            # 100 % 64 != 0
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
        got = KB.attention(q, k, v, causal=True,
                           backend="pallas_interpret",
                           block_q=64, block_k=64)
        want = ref.attention_ref(
            q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
            causal=True).swapaxes(1, 2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


class TestRMSNormGrads:
    @pytest.mark.parametrize("n,d,br", [
        (64, 128, 64),
        (130, 64, 64),      # ragged tail: last block zero-padded
        (256, 256, 128),
    ])
    def test_matches_oracle_grads(self, n, d, br):
        x = jax.random.normal(KEY, (n, d), jnp.float32)
        s = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (d,))
        got = _grads(lambda x, s: rmsnorm(x, s, block_rows=br,
                                          interpret=True), x, s)
        want = _grads(ref.rmsnorm_ref, x, s)
        _assert_grads_close(got, want, atol=2e-5)


class TestSSDGrads:
    def test_matches_xla_grads(self):
        """The Pallas SSD bwd recomputes through the XLA chunk scan, so
        its gradients must match the XLA path essentially exactly."""
        from repro.models.mamba2 import ssd_chunked
        ks = jax.random.split(KEY, 5)
        B, S, H, P, N = 1, 96, 2, 16, 8
        xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
        Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
        D = jnp.ones((H,)) * 0.5
        got = _grads(lambda *a: ssd_full(*a, chunk=32, interpret=True),
                     xh, dt, A, Bm, Cm, D)
        want = _grads(lambda *a: ssd_chunked(*a, chunk=32),
                      xh, dt, A, Bm, Cm, D)
        _assert_grads_close(got, want, atol=1e-6)


class TestRaggedPagedAttention:
    """The serving decode kernel: one query token per request against
    that request's ragged KV depth (``lengths[b]`` cached tokens plus
    the just-written one).  The xla entry is bitwise-pinned to the dense
    decode path in tests/test_serving.py; here the Pallas kernel
    (interpret mode) is held against that xla oracle."""

    @pytest.mark.parametrize("B,H,Hkv,hd,Skv", [
        (2, 2, 2, 32, 64),      # MHA
        (3, 4, 2, 32, 40),      # GQA, ragged Skv vs block_k
        (2, 4, 1, 64, 128),     # MQA, block-aligned
    ])
    def test_matches_xla_oracle(self, B, H, Hkv, hd, Skv):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, Skv, Hkv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, Skv, Hkv, hd), jnp.float32)
        # depths: first decode (0), a block boundary, and the deepest
        lengths = jnp.asarray([0, min(Skv - 1, 31), Skv - 1][:B],
                              jnp.int32)
        want = KB.paged_decode_attention(q, k, v, lengths,
                                         backend="xla")
        got = KB.paged_decode_attention(q, k, v, lengths,
                                        backend="pallas_interpret")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_block_k_independence(self):
        from repro.kernels.paged import ragged_decode_attention
        ks = jax.random.split(KEY, 3)
        B, H, Hkv, hd, Skv = 2, 2, 1, 32, 96
        q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, Skv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, Skv, hd), jnp.float32)
        lengths = jnp.asarray([17, 90], jnp.int32)
        o1 = ragged_decode_attention(q, k, v, lengths, block_k=32,
                                     interpret=True)
        o2 = ragged_decode_attention(q, k, v, lengths, block_k=96,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=1e-5, rtol=1e-5)

    def test_stale_tail_masked(self):
        """Positions beyond lengths[b] must not leak into the output —
        the serving pool reuses pages without zeroing them."""
        ks = jax.random.split(KEY, 3)
        B, H, Hkv, hd, Skv = 2, 2, 2, 32, 64
        q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, Skv, Hkv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, Skv, Hkv, hd), jnp.float32)
        lengths = jnp.asarray([7, 33], jnp.int32)
        mask = (jnp.arange(Skv)[None, :, None, None]
                <= lengths[:, None, None, None])
        a = KB.paged_decode_attention(q, k, v, lengths,
                                      backend="pallas_interpret")
        b = KB.paged_decode_attention(
            q, jnp.where(mask, k, 1e3), jnp.where(mask, v, -1e3),
            lengths, backend="pallas_interpret")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


class TestBackendRegistry:
    def test_resolve_rejects_unknown(self):
        with pytest.raises(ValueError, match="kernel backend"):
            KB.resolve("cudnn")

    def test_rmsnorm_xla_entry_is_ref(self):
        x = jax.random.normal(KEY, (8, 32))
        s = jnp.full((32,), 0.25)
        got = KB.rmsnorm(x, s, backend="xla")
        want = ref.rmsnorm_ref(x, s)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_layers_rmsnorm_delegates(self):
        from repro.models.layers import rmsnorm as layers_rmsnorm
        x = jax.random.normal(KEY, (4, 16, 32))
        s = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (32,))
        np.testing.assert_array_equal(
            np.asarray(layers_rmsnorm(x, s)),
            np.asarray(ref.rmsnorm_ref(x, s)))
