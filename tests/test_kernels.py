"""Per-kernel shape/dtype sweeps, assert_allclose vs the ref.py oracles
(Pallas executed with interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd import ssd_full

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,Hkv,S,hd", [
        (2, 4, 2, 256, 64),     # GQA
        (1, 8, 8, 128, 128),    # MHA, MXU-square blocks
        (2, 4, 1, 512, 32),     # MQA
        (1, 2, 2, 384, 64),     # non-pow2 sequence
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_oracle(self, B, H, Hkv, S, hd, causal):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, S, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, S, hd), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, interpret=True)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 4, 256, 64)).astype(dtype)
        k = jax.random.normal(ks[1], (1, 2, 256, 64)).astype(dtype)
        v = jax.random.normal(ks[2], (1, 2, 256, 64)).astype(dtype)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        want = ref.attention_ref(q, k, v, causal=True)
        assert out.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))

    def test_block_shape_independence(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 512, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 512, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 512, 64), jnp.float32)
        o1 = flash_attention(q, k, v, block_q=128, block_k=128,
                             interpret=True)
        o2 = flash_attention(q, k, v, block_q=64, block_k=256,
                             interpret=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=1e-5, rtol=1e-5)


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(4, 128), (2, 33, 256), (512,),
                                       (3, 5, 7, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, shape, dtype):
        x = jax.random.normal(KEY, shape).astype(dtype)
        s = (jax.random.normal(jax.random.PRNGKey(1), (shape[-1],))
             * 0.1).astype(dtype)
        out = rmsnorm(x, s, interpret=True)
        want = ref.rmsnorm_ref(x, s)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))

    def test_row_blocking_boundary(self):
        x = jax.random.normal(KEY, (130, 64))   # not a block multiple
        s = jnp.zeros((64,))
        out = rmsnorm(x, s, block_rows=64, interpret=True)
        want = ref.rmsnorm_ref(x, s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=1e-6)


class TestSSD:
    @pytest.mark.parametrize("B,S,H,P,N,Q", [
        (2, 96, 4, 32, 16, 32),
        (1, 128, 2, 64, 32, 64),
        (2, 100, 3, 16, 8, 32),   # padding path
    ])
    def test_matches_naive_recurrence(self, B, S, H, P, N, Q):
        ks = jax.random.split(KEY, 5)
        xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, N))
        Cm = jax.random.normal(ks[4], (B, S, N))
        D = jnp.ones((H,)) * 0.5
        y, h = ssd_full(xh, dt, A, Bm, Cm, D, chunk=Q, interpret=True)
        yr, hr = ref.ssd_ref(xh, dt, A, Bm, Cm, D)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   atol=5e-4, rtol=5e-4)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                                   atol=5e-4, rtol=5e-4)

    def test_model_path_matches_kernel(self):
        """models.mamba2.ssd_chunked (XLA path) ≡ kernels.ssd (Pallas)."""
        from repro.models.mamba2 import ssd_chunked
        ks = jax.random.split(KEY, 5)
        B, S, H, P, N = 2, 64, 2, 16, 8
        xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, N))
        Cm = jax.random.normal(ks[4], (B, S, N))
        D = jnp.zeros((H,))
        y1, h1 = ssd_chunked(xh, dt, A, Bm, Cm, D, chunk=16)
        y2, h2 = ssd_full(xh, dt, A, Bm, Cm, D, chunk=16, interpret=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   atol=1e-4, rtol=1e-4)
