import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.data import MarkovLM, PhaseDataLoader
from repro.train import checkpoint as CKPT
from repro.train.trainer import Trainer, make_train_step
from repro.optim import optimizers as O

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                   n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                   vocab_size=128, max_seq_len=64, rope_theta=1e4)


def _cfg(kind="seesaw", steps=40, b0=4, **kw):
    return RunConfig(model=TINY,
                     schedule=ScheduleConfig(kind=kind, base_lr=1e-3,
                                             alpha=2.0, n_cuts=2),
                     optimizer=OptimizerConfig(kind="adamw"),
                     seq_len=32, global_batch_size=b0,
                     total_tokens=32 * b0 * steps, remat=False, **kw)


class TestTrainer:
    def test_batch_ramp_recompiles_once_per_size(self):
        cfg = _cfg()
        tr = Trainer(cfg)
        loader = PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32)
        tr.run(loader)
        sizes = {h["batch_size"] for h in tr.history}
        assert len(tr._step_cache) == len(sizes) >= 3

    def test_loss_decreases(self):
        cfg = _cfg(kind="cosine", steps=60)
        tr = Trainer(cfg)
        loader = PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32)
        hist = tr.run(loader)
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        assert last < first

    def test_lr_follows_plan(self):
        cfg = _cfg(kind="seesaw", steps=60)
        tr = Trainer(cfg)
        loader = PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, 32)
        hist = tr.run(loader)
        by_phase = {}
        for h in hist:
            by_phase.setdefault(h["phase"], h["lr"])
        lrs = [by_phase[k] for k in sorted(by_phase) if k > 0]
        for a, b in zip(lrs, lrs[1:]):
            assert b == pytest.approx(a / np.sqrt(2), rel=1e-3)

    def test_seesaw_fewer_steps_same_tokens(self):
        c1 = _cfg(kind="cosine", steps=80)
        c2 = _cfg(kind="seesaw", steps=80)
        t1, t2 = Trainer(c1), Trainer(c2)
        h1 = t1.run(PhaseDataLoader(MarkovLM(128, seed=0), t1.plan, 32))
        h2 = t2.run(PhaseDataLoader(MarkovLM(128, seed=0), t2.plan, 32))
        assert len(h2) < len(h1)
        assert abs(h2[-1]["tokens"] - h1[-1]["tokens"]) \
            <= t2.plan.phases[-1].batch_size * 32


class TestMicroBatching:
    def test_grad_accum_matches_full_batch(self):
        """With a linear optimizer (SGD) accumulation order is the only
        difference ⇒ params match to f32 noise.  (Adam's sign-like step
        amplifies ±1e-7 grad noise on near-zero coordinates, so it is
        not a valid equality probe.)"""
        cfg = _cfg()
        opt = O.sgd(grad_clip=0.0)
        step1 = make_train_step(cfg, opt, micro_batches=1)
        step4 = make_train_step(cfg, opt, micro_batches=4)
        from repro.models import registry as R
        params = R.init_params(jax.random.PRNGKey(0), TINY)
        st = opt.init(params)
        batch = R.concrete_inputs(TINY, "train", 8, 32)
        p1, _, m1 = step1(params, st, batch, jnp.asarray(1e-1))
        p4, _, m4 = step4(params, st, batch, jnp.asarray(1e-1))
        assert float(m1["loss"]) == pytest.approx(float(m4["loss"]),
                                                  rel=1e-4)
        # f32 reduction-order noise across the 4-way accumulation at
        # lr=0.1 bounds equality at ~1e-5 of the update magnitude
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4, rtol=1e-3)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        from repro.models import registry as R
        params = R.init_params(jax.random.PRNGKey(0), TINY)
        opt = O.adamw()
        st = opt.init(params)
        path = str(tmp_path / "ckpt.npz")
        CKPT.save(path, params, st, step=7, tokens_seen=1234.0)
        p2, s2, meta = CKPT.restore(path, params, st)
        assert meta["step"] == 7 and meta["tokens_seen"] == 1234.0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert jax.tree.structure(s2) == jax.tree.structure(st)

    def test_resume_continues_identically(self, tmp_path):
        """Train 10 steps, checkpoint, train 10 more — equals 20
        straight (same data stream by absolute sequence index)."""
        cfg = _cfg(kind="cosine", steps=20)
        src = MarkovLM(128, seed=0)

        tr = Trainer(cfg)
        full = tr.run(PhaseDataLoader(src, tr.plan, 32), max_steps=20)

        tr2 = Trainer(cfg)
        tr2.run(PhaseDataLoader(src, tr2.plan, 32), max_steps=10)
        path = str(tmp_path / "mid.npz")
        CKPT.save(path, tr2.state.params, tr2.state.opt_state,
                  tr2.state.step, tr2.state.tokens_seen)
        tr3 = Trainer(cfg)
        p, s, meta = CKPT.restore(path, tr3.state.params,
                                  tr3.state.opt_state)
        tr3.state.params, tr3.state.opt_state = p, s
        tr3.state.step = meta["step"]
        tr3.state.tokens_seen = meta["tokens_seen"]
        # skip the first 10 steps' data
        loader = PhaseDataLoader(src, tr3.plan, 32)
        it = iter(loader)
        for _ in range(10):
            next(it)
        tr3.run(it, max_steps=20)
        np.testing.assert_allclose(
            float(full[-1]["loss"]), tr3.history[-1]["loss"], rtol=1e-4)


class TestServer:
    def test_generate_batched(self):
        from repro.models import registry as R
        from repro.train.serve import Server
        params = R.init_params(jax.random.PRNGKey(0), TINY)
        srv = Server(TINY, params, max_len=64)
        prompts = np.random.default_rng(0).integers(0, 128, (3, 8))
        out = srv.generate(prompts, 5)
        assert out.shape == (3, 5)
        assert (out >= 0).all() and (out < TINY.padded_vocab).all()

    def test_greedy_deterministic(self):
        from repro.models import registry as R
        from repro.train.serve import Server
        params = R.init_params(jax.random.PRNGKey(0), TINY)
        srv = Server(TINY, params, max_len=64)
        prompts = np.random.default_rng(0).integers(0, 128, (2, 8))
        a = srv.generate(prompts, 4)
        b = srv.generate(prompts, 4)
        np.testing.assert_array_equal(a, b)
