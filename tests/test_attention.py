"""Chunked-attention (the XLA/distributed path) correctness: causal,
windows, GQA, block-skip, ring caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models import attention as A

KEY = jax.random.PRNGKey(3)


def _qkv(B, H, Hkv, S, hd):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    return q, k, v


def _ref(q, k, v, causal=True, window=None):
    """Oracle in (B,S,H,hd) layout with optional sliding window."""
    qq = q.transpose(0, 2, 1, 3)
    kk = k.transpose(0, 2, 1, 3)
    vv = v.transpose(0, 2, 1, 3)
    B, H, S, hd = qq.shape
    Hkv = kk.shape[1]
    G = H // Hkv
    kk = jnp.repeat(kk, G, axis=1)
    vv = jnp.repeat(vv, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qq, kk) / np.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    m = jnp.ones((S, S), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vv)
    return o.transpose(0, 2, 1, 3)


@pytest.mark.parametrize("chunk", [16, 64, 1024])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_ref(chunk, causal):
    q, k, v = _qkv(2, 4, 2, 96, 32)
    out = A.chunked_attention(q, k, v, causal=causal, chunk=chunk)
    want = _ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("window", [8, 32, 1000])
def test_sliding_window(window):
    q, k, v = _qkv(1, 2, 1, 64, 16)
    out = A.chunked_attention(q, k, v, causal=True, window=window,
                              chunk=16)
    want = _ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_block_skip_matches_baseline():
    q, k, v = _qkv(1, 2, 2, 128, 16)
    base = A.chunked_attention(q, k, v, causal=True, chunk=32)
    skip = A.chunked_attention(q, k, v, causal=True, chunk=32,
                               block_skip=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(skip),
                               atol=1e-5, rtol=1e-5)


def test_ring_cache_decode_matches_full():
    """Ring-cache decode (windowed) ≡ full-cache decode with window mask,
    across a run of steps that wraps the ring."""
    B, H, Hkv, hd, W = 1, 2, 1, 16, 8
    params = A.init_attention(jax.random.PRNGKey(0), 32, H, Hkv, hd, 2)
    S0 = 12
    xs = jax.random.normal(jax.random.PRNGKey(1), (B, S0 + 6, 32),
                           jnp.float32)
    # full-forward oracle with window
    out_full, _ = A.attn_forward(params, xs, n_heads=H, n_kv_heads=Hkv,
                                 head_dim=hd, rope_theta=10.0,
                                 causal=True, window=W, chunk=8)
    # prefill S0 then decode 6 with the ring cache
    h_pre = xs[:, :S0]
    _, (k, v) = A.attn_forward(params, h_pre, n_heads=H, n_kv_heads=Hkv,
                               head_dim=hd, rope_theta=10.0, causal=True,
                               window=W, chunk=8)
    cache = A.ring_from_prefill(k, v, S0, W, dtype=jnp.float32)
    for t in range(6):
        o, cache = A.decode_attn(params, xs[:, S0 + t:S0 + t + 1], cache,
                                 jnp.asarray(S0 + t), n_heads=H,
                                 n_kv_heads=Hkv, head_dim=hd,
                                 rope_theta=10.0, window=W)
        np.testing.assert_allclose(
            np.asarray(o), np.asarray(out_full[:, S0 + t:S0 + t + 1]),
            atol=1e-4, rtol=1e-4)


def test_full_cache_decode_matches_forward():
    B, H, Hkv, hd = 2, 4, 2, 16
    params = A.init_attention(jax.random.PRNGKey(0), 32, H, Hkv, hd, 2)
    S = 20
    xs = jax.random.normal(jax.random.PRNGKey(1), (B, S + 1, 32))
    out_full, _ = A.attn_forward(params, xs, n_heads=H, n_kv_heads=Hkv,
                                 head_dim=hd, rope_theta=100.0,
                                 causal=True, chunk=8)
    _, (k, v) = A.attn_forward(params, xs[:, :S], n_heads=H,
                               n_kv_heads=Hkv, head_dim=hd,
                               rope_theta=100.0, causal=True, chunk=8)
    pad = 8
    cache = {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
             "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))}
    o, _ = A.decode_attn(params, xs[:, S:S + 1], cache, jnp.asarray(S),
                         n_heads=H, n_kv_heads=Hkv, head_dim=hd,
                         rope_theta=100.0)
    np.testing.assert_allclose(np.asarray(o),
                               np.asarray(out_full[:, S:S + 1]),
                               atol=1e-4, rtol=1e-4)
