"""Per-host row-block layout assertion (``launch.mesh``).

The pure check behind ``assert_per_host_row_blocks`` is exercised with
synthetic device→slice layouts (a real multi-process mesh cannot be
built in the single-process fast tier; the 2-process launch test
drives the full path): contiguous process-ordered blocks pass,
interleaved/permuted/indivisible layouts raise.
"""
from dataclasses import dataclass

import pytest

from repro.launch.mesh import (_row_blocks_by_process,
                               check_per_host_row_blocks,
                               data_parallel_size)


@dataclass(frozen=True)
class FakeDev:
    process_index: int
    did: int = 0


def _imap(assignments):
    """{(process, slice start, stop)} → devices_indices_map shape."""
    return {FakeDev(p, i): (slice(a, b),)
            for i, (p, a, b) in enumerate(assignments)}


class TestRowBlockCheck:
    def test_contiguous_process_order_passes(self):
        per = _row_blocks_by_process(
            _imap([(0, 0, 2), (0, 2, 4), (1, 4, 6), (1, 6, 8)]), 8)
        check_per_host_row_blocks(per, 8, 2)

    def test_single_process_owns_everything(self):
        per = _row_blocks_by_process(_imap([(0, 0, 4)]), 4)
        check_per_host_row_blocks(per, 4, 1)

    def test_interleaved_rows_rejected(self):
        """A custom mesh whose device order interleaves processes
        along the data axis would silently feed wrong rows."""
        per = _row_blocks_by_process(
            _imap([(0, 0, 1), (1, 1, 2), (0, 2, 3), (1, 3, 4)]), 4)
        with pytest.raises(ValueError, match="contiguous block"):
            check_per_host_row_blocks(per, 4, 2)

    def test_process_order_swap_rejected(self):
        """Contiguous blocks in the wrong process order are just as
        wrong: process 0 would sample rows process 1's devices own."""
        per = _row_blocks_by_process(
            _imap([(1, 0, 2), (0, 2, 4)]), 4)
        with pytest.raises(ValueError, match="process order"):
            check_per_host_row_blocks(per, 4, 2)

    def test_indivisible_width_rejected(self):
        per = _row_blocks_by_process(_imap([(0, 0, 3)]), 3)
        with pytest.raises(ValueError, match="does not divide"):
            check_per_host_row_blocks(per, 3, 2)

    def test_full_slice_normalized(self):
        """slice(None) entries (replicated specs) count as the whole
        axis."""
        per = _row_blocks_by_process(
            {FakeDev(0): (slice(None),)}, 4)
        assert per == {0: {0, 1, 2, 3}}


class TestDataParallelSize:
    def test_mesh_shapes(self):
        class M:
            shape = {"data": 4, "model": 2}
        assert data_parallel_size(M()) == 4
        assert data_parallel_size(None) == 1
