"""End-to-end behaviour tests for the paper's system: Seesaw (Algorithm
1) as a drop-in replacement for cosine — same loss at equal tokens, fewer
serial steps (Figure 1 at reduced scale)."""
import numpy as np
import pytest

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.core.seesaw import build_plan, measured_speedup
from repro.data import MarkovLM, PhaseDataLoader
from repro.train.trainer import Trainer

MODEL = ModelConfig(name="sys-tiny", arch_type="dense", n_layers=2,
                    d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                    d_ff=256, vocab_size=256, max_seq_len=64,
                    rope_theta=1e4)


def _run(kind, steps=120, seed=0, alpha=2.0, n_cuts=4, beta=None):
    cfg = RunConfig(model=MODEL,
                    schedule=ScheduleConfig(kind=kind, base_lr=3e-3,
                                            alpha=alpha, n_cuts=n_cuts,
                                            beta=beta or alpha),
                    optimizer=OptimizerConfig(kind="adamw"),
                    seq_len=64, global_batch_size=8,
                    total_tokens=64 * 8 * steps, remat=False, seed=seed)
    tr = Trainer(cfg)
    hist = tr.run(PhaseDataLoader(MarkovLM(256, branching=8, seed=seed),
                                  tr.plan, 64))
    return tr, hist


@pytest.fixture(scope="module")
def runs():
    tr_c, h_c = _run("cosine")
    tr_s, h_s = _run("seesaw")
    return tr_c, h_c, tr_s, h_s


class TestSeesawVsCosine:
    def test_equal_token_budget(self, runs):
        _, h_c, _, h_s = runs
        slack = 64 * 128  # half of one late-phase step
        assert abs(h_c[-1]["tokens"] - h_s[-1]["tokens"]) <= 2 * slack

    def test_fewer_serial_steps(self, runs):
        _, h_c, _, h_s = runs
        assert len(h_s) < len(h_c)

    def test_final_loss_matches(self, runs):
        """The paper's core claim (Table 1): Seesaw matches cosine at
        equal FLOPs.  At this scale we allow a modest tolerance."""
        _, h_c, _, h_s = runs
        lc = np.mean([h["loss"] for h in h_c[-5:]])
        ls = np.mean([h["loss"] for h in h_s[-5:]])
        assert abs(lc - ls) < 0.12, (lc, ls)

    def test_loss_approaches_entropy_floor(self, runs):
        _, h_c, _, _ = runs
        floor = MarkovLM(256, branching=8, seed=0).conditional_entropy()
        final = np.mean([h["loss"] for h in h_c[-5:]])
        assert final < floor + 1.5

    def test_batch_ramp_happened(self, runs):
        _, _, tr_s, h_s = runs
        assert max(h["batch_size"] for h in h_s) >= 8 * 2 ** 3


class TestSpeedupAccounting:
    def test_measured_speedup_near_discrete_prediction(self):
        from repro.core.seesaw import continuous_step_fraction
        see = build_plan(kind="seesaw", base_lr=1.0, total_tokens=2 ** 26,
                         warmup_frac=0.1, b0=32, alpha=2.0, n_cuts=6)
        ref = build_plan(kind="cosine", base_lr=1.0, total_tokens=2 ** 26,
                         warmup_frac=0.1, b0=32, alpha=2.0, n_cuts=6)
        got = measured_speedup(see, ref, 1024)
        # warmup region (10%) is not ramped; prediction applies to the
        # post-warmup span
        pred = 1 - continuous_step_fraction(6, 2.0)
        assert got == pytest.approx(pred * 0.9, abs=0.06)


class TestNaiveRampUnderperforms:
    def test_figure5_ordering(self):
        """Naive constant-LR ramp (Figure 5 blue) ends no better than
        Seesaw at matched token budget."""
        _, h_naive = _run("naive-ramp", steps=120, beta=2.0)
        _, h_see = _run("seesaw", steps=120)
        ln = np.mean([h["loss"] for h in h_naive[-5:]])
        ls = np.mean([h["loss"] for h in h_see[-5:]])
        assert ls <= ln + 0.05
