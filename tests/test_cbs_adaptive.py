"""Tests for the beyond-paper extensions: CBS (gradient-noise-scale)
estimation and the adaptive (plateau-triggered) Seesaw controller."""
import math

import numpy as np
import pytest

from repro.core import theory as T
from repro.core.adaptive import AdaptiveSeesaw
from repro.core.cbs import (NoiseScaleMonitor, noise_scale_trajectory,
                            noise_scale_two_point)


class TestNoiseScale:
    def test_two_point_estimator_unbiased(self):
        """On synthetic gradients g_b = G + ξ/√b, the estimator recovers
        tr(Σ)/‖G‖²."""
        rng = np.random.default_rng(0)
        d, b, B = 2000, 8, 64
        G = rng.normal(size=d) * 0.1
        sigma = 1.0
        # average many trials: the estimator is unbiased, not low-var
        bn_est = []
        for t in range(200):
            g_small = G + sigma * rng.normal(size=d) / math.sqrt(b)
            g_big = G + sigma * rng.normal(size=d) / math.sqrt(B)
            bn, g2, tr = noise_scale_two_point(
                {"g": g_small}, {"g": g_big}, b, B)
            bn_est.append(tr / max(g2, 1e-30))
        true_bn = sigma ** 2 * d / float(G @ G)
        assert np.median(bn_est) == pytest.approx(true_bn, rel=0.3)

    def test_monitor_ema(self):
        rng = np.random.default_rng(1)
        mon = NoiseScaleMonitor(micro_batch=8, full_batch=64, ema=0.5)
        d = 500
        G = rng.normal(size=d)
        for _ in range(50):
            gs = G + rng.normal(size=d) / math.sqrt(8)
            gb = G + rng.normal(size=d) / math.sqrt(64)
            v = mon.update({"g": gs}, {"g": gb})
        assert v is not None and np.isfinite(v) and v >= 0

    def test_noise_scale_grows_during_training(self):
        """The paper's §2 observation (after McCandlish): the noise
        scale increases over a run — the justification for ramping."""
        lam = T.power_law_spectrum(60, a=1.0)
        eta = T.stability_eta(lam)
        traj = noise_scale_trajectory(lam, 1.0, eta, batch=8,
                                      steps=3000, every=100)
        assert traj[-1] > traj[0] * 3


class TestAdaptiveSeesaw:
    def _loss_stream(self, n, floors):
        """Piecewise exponential decay to successive floors."""
        out = []
        lvl = 1.0
        for f in floors:
            for t in range(n):
                lvl = f + (lvl - f) * 0.97
                out.append(lvl)
        return out

    def test_fires_on_plateau(self):
        ctl = AdaptiveSeesaw(alpha=2.0, window=20, min_steps_between=40)
        fired_at = []
        for i, loss in enumerate(self._loss_stream(300, [0.5])):
            if ctl.observe(loss):
                fired_at.append(i)
        assert ctl.n_cuts >= 1
        # fires only after decay has flattened (~100+ steps at 0.97)
        assert fired_at[0] > 60

    def test_schedule_invariants(self):
        ctl = AdaptiveSeesaw(alpha=2.0, window=10, min_steps_between=20)
        for loss in self._loss_stream(100, [0.5, 0.3, 0.25]):
            ctl.observe(loss)
        # lr_scale and batch_multiplier stay on the Seesaw line
        assert ctl.lr_scale == pytest.approx(
            math.sqrt(2.0) ** (-ctl.n_cuts))
        assert ctl.batch_multiplier == pytest.approx(2.0 ** ctl.n_cuts)
        # the invariant α_s√β per cut equals the reference α
        a_s = math.sqrt(2.0)
        assert a_s * math.sqrt(2.0) == pytest.approx(2.0)

    def test_flat_stream_fires_once_per_plateau(self):
        """Regression (chain-fire bug): after a cut, the stale
        ``_prev_window_mean`` kept the pre-cut plateau mean, so every
        subsequent window on a flat stream re-triggered — one cut per
        ``window`` steps instead of one per plateau.  A descend-then-
        plateau stream must fire exactly once per plateau; the second
        cut needs fresh improvement evidence first."""
        ctl = AdaptiveSeesaw(alpha=2.0, window=20, min_steps_between=20)
        # descend to a floor, then sit on it for many windows
        fired_at = []
        for i, loss in enumerate(self._loss_stream(400, [0.5])):
            if ctl.observe(loss):
                fired_at.append(i)
        # 400 steps ≈ 20 windows at the plateau: pre-fix this fires a
        # cut every window (≈ 10+ cuts); fixed it fires exactly once
        assert ctl.n_cuts == 1, fired_at
        # a second descend-then-plateau earns exactly one more cut
        for loss in self._loss_stream(400, [0.25]):
            ctl.observe(loss)
        assert ctl.n_cuts == 2

    def test_no_cut_while_improving(self):
        ctl = AdaptiveSeesaw(alpha=2.0, window=20, rel_threshold=1e-4)
        lvl = 1.0
        for _ in range(200):
            lvl *= 0.99          # steady improvement, never plateaus
            ctl.observe(lvl)
        assert ctl.n_cuts == 0

    def test_adaptive_matches_prescheduled_risk(self):
        """On the exact NSGD recursions: adaptive cut points (triggered
        by the simulated risk plateau) reach a final risk within a
        constant factor of the cosine-derived schedule."""
        lam = T.power_law_spectrum(80, a=1.0)
        eta = T.stability_eta(lam)
        sigma2, b0 = 1.0, 8
        m0 = T.warm_start(lam, sigma2, eta, b0, 2000)
        eta_n = eta * math.sqrt(sigma2 * np.sum(lam) / b0)

        # prescheduled: 5 equal-sample phases
        ph = T.phase_schedule(eta_n, b0, math.sqrt(2.0), 2.0, [8192] * 5)
        r_sched, _, _ = T.run_schedule(lam, sigma2, ph, m0=m0,
                                       normalized=True,
                                       assume_variance_dominated=True)

        # adaptive: run step-by-step, cut when risk improvement stalls
        ctl = AdaptiveSeesaw(alpha=2.0, window=64, rel_threshold=1e-2,
                             min_steps_between=128, max_cuts=4)
        m = m0.copy()
        e = np.zeros_like(lam)
        import repro.core.theory as TT
        B = float(b0)
        lr = eta_n
        total_samples = 5 * 8192
        seen = 0.0
        while seen < total_samples:
            eff = lr / math.sqrt(sigma2 * np.sum(lam) / B)
            m, e = TT._step(m, e, lam, eff, B, sigma2)
            seen += B
            risk = 0.5 * float(np.dot(lam, m))
            if ctl.observe(risk):
                lr /= math.sqrt(2.0)
                B *= 2.0
        r_adapt = 0.5 * float(np.dot(lam, m))
        assert ctl.n_cuts >= 1            # it did ramp
        assert r_adapt / r_sched[-1] < 3.0
