import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import optimizers as O


def _params():
    return {"w": jnp.asarray([1.0, -2.0, 3.0]),
            "b": jnp.asarray([[0.5, 0.5]])}


def _grads():
    return {"w": jnp.asarray([0.1, 0.2, -0.3]),
            "b": jnp.asarray([[1.0, -1.0]])}


class TestSGD:
    def test_step(self):
        opt = O.sgd()
        p, g = _params(), _grads()
        st = opt.init(p)
        p2, st = opt.update(g, st, p, 0.5)
        np.testing.assert_allclose(p2["w"], p["w"] - 0.5 * g["w"])

    def test_momentum(self):
        opt = O.sgd(momentum=0.9)
        p, g = _params(), _grads()
        st = opt.init(p)
        p1, st = opt.update(g, st, p, 1.0)
        p2, st = opt.update(g, st, p1, 1.0)
        # second step applies (1+0.9)·g
        np.testing.assert_allclose(
            np.asarray(p2["w"]), np.asarray(p["w"] - g["w"] - 1.9 * g["w"]),
            rtol=1e-6)


class TestNSGD:
    def test_unit_norm_update(self):
        """θ ← θ − η g/‖g‖: the applied update has global norm η."""
        opt = O.nsgd()
        p, g = _params(), _grads()
        st = opt.init(p)
        p2, _ = opt.update(g, st, p, 0.25)
        delta = jax.tree.map(lambda a, b: a - b, p, p2)
        norm = float(O._global_norm(delta))
        assert norm == pytest.approx(0.25, rel=1e-5)

    def test_scale_invariance(self):
        """NSGD is invariant to gradient scaling — the Adam-proxy
        property the paper's analysis rests on."""
        opt = O.nsgd()
        p, g = _params(), _grads()
        g10 = jax.tree.map(lambda x: 10.0 * x, g)
        st = opt.init(p)
        p1, _ = opt.update(g, st, p, 0.1)
        p2, _ = opt.update(g10, st, p, 0.1)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)


class TestAdamW:
    def test_first_step_is_signish(self):
        """After bias correction, step 1 ≈ lr·sign(g) for eps→0."""
        opt = O.adamw(beta1=0.9, beta2=0.95, eps=1e-12, grad_clip=0.0)
        p, g = _params(), _grads()
        st = opt.init(p)
        p2, _ = opt.update(g, st, p, 1e-3)
        step = np.asarray(p["w"] - p2["w"])
        np.testing.assert_allclose(step, 1e-3 * np.sign(g["w"]), rtol=1e-4)

    def test_weight_decay_decoupled(self):
        opt_wd = O.adamw(weight_decay=0.1, grad_clip=0.0)
        opt_no = O.adamw(weight_decay=0.0, grad_clip=0.0)
        p, g = _params(), _grads()
        p_wd, _ = opt_wd.update(g, opt_wd.init(p), p, 1e-2)
        p_no, _ = opt_no.update(g, opt_no.init(p), p, 1e-2)
        diff = np.asarray(p_no["w"] - p_wd["w"])
        np.testing.assert_allclose(diff, 1e-2 * 0.1 * np.asarray(p["w"]),
                                   rtol=1e-3)

    def test_grad_clip(self):
        opt = O.adamw(grad_clip=0.1)
        p = _params()
        huge = jax.tree.map(lambda x: 1e6 * jnp.ones_like(x), p)
        p2, _ = opt.update(huge, opt.init(p), p, 1e-3)
        assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p2))

    def test_matches_manual_two_steps(self):
        b1, b2, eps, lr = 0.9, 0.95, 1e-8, 3e-3
        opt = O.adamw(b1, b2, eps, 0.0, grad_clip=0.0)
        p = {"w": jnp.asarray([1.0])}
        g1 = {"w": jnp.asarray([0.4])}
        g2 = {"w": jnp.asarray([-0.2])}
        st = opt.init(p)
        p1, st = opt.update(g1, st, p, lr)
        p2, st = opt.update(g2, st, p1, lr)
        # manual
        m = 0.1 * 0.4
        v = 0.05 * 0.16
        w = 1.0 - lr * (m / 0.1) / (np.sqrt(v / 0.05) + eps)
        m = b1 * m + 0.1 * (-0.2)
        v = b2 * v + 0.05 * 0.04
        w = w - lr * (m / (1 - b1 ** 2)) / (np.sqrt(v / (1 - b2 ** 2)) + eps)
        assert float(p2["w"][0]) == pytest.approx(w, rel=1e-6)


def test_from_config_dispatch():
    from repro.configs import OptimizerConfig
    for kind in ("adamw", "adam", "sgd", "nsgd"):
        opt = O.from_config(OptimizerConfig(kind=kind))
        p = _params()
        p2, _ = opt.update(_grads(), opt.init(p), p, 1e-3)
        assert jax.tree.structure(p2) == jax.tree.structure(p)
