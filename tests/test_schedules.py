import math

import pytest

from repro.core import schedules as S
from repro.core import seesaw as SS


class TestCosine:
    def test_warmup_then_decay(self):
        lr = S.cosine_lr(1.0, 1000.0, 100.0)
        assert float(lr(0.0)) == 0.0
        assert float(lr(50.0)) == pytest.approx(0.5)
        assert float(lr(100.0)) == pytest.approx(1.0, abs=1e-6)
        assert float(lr(1000.0)) == pytest.approx(0.0, abs=1e-6)

    def test_quarter_cosine_lemma1_form(self):
        lr = S.quarter_cosine_lr(2.0, 1000.0, 0.0)
        assert float(lr(0.0)) == pytest.approx(2.0)
        assert float(lr(500.0)) == pytest.approx(2.0 * math.cos(math.pi / 4),
                                                 rel=1e-5)
        assert float(lr(1000.0)) == pytest.approx(0.0, abs=1e-6)

    def test_cut_points_match_curve(self):
        total, warm, alpha = 10_000.0, 1_000.0, 2.0
        cuts = S.cosine_cut_points(total, warm, alpha, 3, quarter=True)
        lr = S.quarter_cosine_lr(1.0, total, warm)
        for k, c in enumerate(cuts, start=1):
            assert float(lr(c)) == pytest.approx(alpha ** (-k), rel=1e-4)

    def test_cut_points_monotone(self):
        cuts = S.cosine_cut_points(1e6, 1e5, 1.1, 12)
        assert all(a < b for a, b in zip(cuts, cuts[1:]))


class TestStepDecay:
    def test_matches_alpha_powers(self):
        lr = S.step_decay_lr(1.0, [100.0, 200.0], 2.0, 10.0)
        assert float(lr(50.0)) == pytest.approx(1.0)
        assert float(lr(150.0)) == pytest.approx(0.5)
        assert float(lr(250.0)) == pytest.approx(0.25)

    def test_warmup(self):
        lr = S.step_decay_lr(1.0, [100.0], 2.0, 10.0)
        assert float(lr(5.0)) == pytest.approx(0.5)


class TestPlan:
    def test_seesaw_keeps_product(self):
        """Algorithm 1: step-decay cuts α; seesaw cuts √α and ramps ×α —
        the Corollary-1 invariant α·√β is identical."""
        ref = SS.build_plan(kind="step", base_lr=1.0, total_tokens=1e6,
                            warmup_frac=0.1, b0=32, alpha=2.0, n_cuts=5)
        see = SS.build_plan(kind="seesaw", base_lr=1.0, total_tokens=1e6,
                            warmup_frac=0.1, b0=32, alpha=2.0, n_cuts=5)
        assert ref.alpha * math.sqrt(ref.beta) == pytest.approx(
            see.alpha * math.sqrt(see.beta))

    def test_seesaw_batches_double(self):
        p = SS.build_plan(kind="seesaw", base_lr=1.0, total_tokens=1e6,
                          warmup_frac=0.1, b0=32, alpha=2.0, n_cuts=4)
        assert p.batch_sizes() == [32, 64, 128, 256, 512]
        scales = [ph.lr_scale for ph in p.phases]
        for a, b in zip(scales, scales[1:]):
            assert b / a == pytest.approx(1 / math.sqrt(2))

    def test_divergent_plan_rejected(self):
        """Lemma 4: α < √β must raise."""
        with pytest.raises(ValueError):
            SS.build_plan(kind="seesaw-general", base_lr=1.0,
                          total_tokens=1e6, warmup_frac=0.1, b0=32,
                          alpha=1.0, beta=4.0, n_cuts=4)

    def test_max_batch_cap(self):
        p = SS.build_plan(kind="seesaw", base_lr=1.0, total_tokens=1e6,
                          warmup_frac=0.1, b0=32, alpha=2.0, n_cuts=6,
                          max_batch_size=128)
        assert max(p.batch_sizes()) == 128

    def test_token_conservation(self):
        for kind in ("cosine", "step", "seesaw"):
            p = SS.build_plan(kind=kind, base_lr=1.0, total_tokens=2 ** 24,
                              warmup_frac=0.1, b0=16, alpha=2.0, n_cuts=5)
            seq = 256
            sched = p.total_tokens_scheduled(seq)
            # conserved to within half of one final-phase step
            slack = p.phases[-1].batch_size * seq / 2 + 1
            assert abs(sched - 2 ** 24) <= slack, kind


class TestLemma1:
    def test_theoretical_value(self):
        assert SS.theoretical_speedup() == pytest.approx(1 - 2 / math.pi)

    def test_discrete_plan_approaches_continuous(self):
        """Finer step-decay approximations converge to the 2/π limit."""
        fr_coarse = SS.continuous_step_fraction(4, 2.0)
        fr_fine = SS.continuous_step_fraction(60, 1.05)
        assert abs(fr_fine - 2 / math.pi) < abs(fr_coarse - 2 / math.pi)
        assert fr_fine == pytest.approx(2 / math.pi, abs=0.02)

    def test_measured_speedup_on_plans(self):
        see = SS.build_plan(kind="seesaw", base_lr=1.0, total_tokens=2 ** 28,
                            warmup_frac=0.1, b0=32, alpha=1.1, n_cuts=40)
        ref = SS.build_plan(kind="cosine", base_lr=1.0, total_tokens=2 ** 28,
                            warmup_frac=0.1, b0=32, alpha=1.1, n_cuts=40)
        sp = SS.measured_speedup(see, ref, seq_len=1024)
        # α=1.1 with deep cuts ≈ paper's setting: ≈30–36% fewer steps
        assert 0.25 < sp < 0.40
