"""The sharded streaming checkpoint format (PR 5), host level.

Directory layout + manifest commit, bounded-memory streaming through
the single ``_to_host`` choke point, exact-int ``tokens_seen``
round-trips, overwrite of a stale checkpoint directory, and the
legacy-migration path: a pre-PR-5 single-file ``.npz`` checkpoint
(float ``tokens_seen`` included) restores through the new restore
code, both directly and via ``Trainer.restore_checkpoint``.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.data import MarkovLM, PhaseDataLoader
from repro.models import registry as R
from repro.optim import optimizers as O
from repro.train import checkpoint as CKPT
from repro.train.trainer import Trainer

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                   d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                   d_ff=128, vocab_size=128, max_seq_len=64,
                   rope_theta=1e4)


def _cfg(kind="seesaw", steps=24):
    return RunConfig(
        model=TINY,
        schedule=ScheduleConfig(kind=kind, base_lr=1e-3, alpha=2.0,
                                n_cuts=2),
        optimizer=OptimizerConfig(kind="adamw"),
        seq_len=32, global_batch_size=8,
        total_tokens=32 * 8 * steps, remat=False, dtype="float32")


def _state():
    params = R.init_params(jax.random.PRNGKey(0), TINY)
    opt = O.adamw()
    return params, opt.init(params)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert np.dtype(x.dtype) == np.dtype(y.dtype)


class TestDirectoryFormat:
    def test_layout_and_roundtrip(self, tmp_path):
        params, st = _state()
        base = str(tmp_path / "ck")
        CKPT.save(base, params, st, step=3, tokens_seen=768)
        assert os.path.isfile(os.path.join(base, "manifest.json"))
        assert os.path.isfile(os.path.join(base, "meta.json"))
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)
        # every leaf indexed, every shard file on disk, one file per
        # block (single device: one block per leaf)
        n_leaves = len(jax.tree.leaves(params)) + len(jax.tree.leaves(st))
        assert len(manifest["arrays"]) == n_leaves
        for entry in manifest["arrays"].values():
            assert len(entry["shards"]) == 1
            assert os.path.isfile(os.path.join(base,
                                               entry["shards"][0]["file"]))
        p2, s2, meta = CKPT.restore(base, params, st)
        assert meta["step"] == 3 and meta["tokens_seen"] == 768
        _assert_trees_equal(params, p2)
        _assert_trees_equal(st, s2)

    def test_npz_suffix_is_stripped(self, tmp_path):
        """``--checkpoint ck.npz`` keeps working: the directory lands
        at the stripped base and restore accepts either name."""
        params, st = _state()
        path = str(tmp_path / "ck.npz")
        CKPT.save(path, params, st, step=1, tokens_seen=0)
        assert os.path.isdir(str(tmp_path / "ck"))
        p2, _, _ = CKPT.restore(path, params, st)
        _assert_trees_equal(params, p2)

    def test_tokens_seen_int_exact_past_2_53(self, tmp_path):
        """JSON ints are arbitrary precision: a token count no float64
        can represent round-trips exactly."""
        params, st = _state()
        big = 2 ** 53 + 1
        base = str(tmp_path / "ck")
        CKPT.save(base, params, st, step=9, tokens_seen=big)
        _, _, meta = CKPT.restore(base, params, st)
        assert meta["tokens_seen"] == big
        assert isinstance(meta["tokens_seen"], int)
        # the trainer-side conversion must not round through float64
        assert CKPT.exact_tokens(meta["tokens_seen"]) == big
        assert CKPT.exact_tokens(2816.0) == 2816

    def test_overwrite_replaces_generation(self, tmp_path):
        """A second save commits a new generation and garbage-collects
        the superseded one — exactly one generation dir survives."""
        params, st = _state()
        base = str(tmp_path / "ck")
        CKPT.save(base, params, st, step=1, tokens_seen=10)
        assert os.listdir(os.path.join(base, "arrays")) == ["0"]
        params2 = jax.tree.map(lambda x: x + 1, params)
        CKPT.save(base, params2, st, step=2, tokens_seen=20)
        assert os.listdir(os.path.join(base, "arrays")) == ["1"]
        p2, _, meta = CKPT.restore(base, params, st)
        assert meta["step"] == 2
        _assert_trees_equal(params2, p2)

    def test_interrupted_save_keeps_previous_checkpoint(self, tmp_path,
                                                        monkeypatch):
        """A save killed mid-stream must leave the previously
        committed checkpoint fully restorable (the new generation
        never commits), and the next successful save must clean the
        orphaned partial generation."""
        params, st = _state()
        base = str(tmp_path / "ck")
        CKPT.save(base, params, st, step=1, tokens_seen=10)

        calls = {"n": 0}
        orig = CKPT._stream_write

        def dying(*a, **kw):
            calls["n"] += 1
            if calls["n"] > 3:
                raise RuntimeError("simulated preemption")
            return orig(*a, **kw)

        monkeypatch.setattr(CKPT, "_stream_write", dying)
        params2 = jax.tree.map(lambda x: x + 1, params)
        with pytest.raises(RuntimeError, match="preemption"):
            CKPT.save(base, params2, st, step=2, tokens_seen=20)
        monkeypatch.setattr(CKPT, "_stream_write", orig)

        p1, _, meta = CKPT.restore(base, params, st)
        assert meta["step"] == 1                  # old commit intact
        _assert_trees_equal(params, p1)
        # partial generation 1 on disk, ignored by restore; the next
        # save reuses the number after GC and commits cleanly
        CKPT.save(base, params2, st, step=3, tokens_seen=30)
        assert os.listdir(os.path.join(base, "arrays")) == ["1"]
        p2, _, meta = CKPT.restore(base, params, st)
        assert meta["step"] == 3
        _assert_trees_equal(params2, p2)

    def test_missing_checkpoint_raises(self, tmp_path):
        params, st = _state()
        with pytest.raises(FileNotFoundError, match="manifest"):
            CKPT.restore(str(tmp_path / "nope"), params, st)


class TestBoundedStreaming:
    def test_save_never_fetches_more_than_chunk(self, tmp_path,
                                                monkeypatch):
        """Every device→host transfer of the save path goes through
        ``_to_host`` and moves at most ``chunk_bytes`` — the property
        that makes the format work for >RAM params."""
        params, st = _state()
        sizes = []
        orig = CKPT._to_host

        def spy(x):
            out = orig(x)
            sizes.append(out.nbytes)
            return out

        monkeypatch.setattr(CKPT, "_to_host", spy)
        chunk = 1 << 12
        CKPT.save(str(tmp_path / "ck"), params, st, step=0,
                  tokens_seen=0, chunk_bytes=chunk)
        leaves = jax.tree.leaves(params) + jax.tree.leaves(st)
        assert sizes, "no transfers recorded"
        assert max(sizes) <= chunk
        # and the big embedding leaf really was split across calls
        total = sum(x.nbytes for x in leaves)
        assert len(sizes) > len(leaves)
        assert sum(sizes) == total

    def test_chunked_write_is_bitwise(self, tmp_path):
        params, st = _state()
        CKPT.save(str(tmp_path / "a"), params, st, step=0, tokens_seen=0,
                  chunk_bytes=1 << 10)
        CKPT.save(str(tmp_path / "b"), params, st, step=0, tokens_seen=0)
        pa, sa, _ = CKPT.restore(str(tmp_path / "a"), params, st)
        pb, sb, _ = CKPT.restore(str(tmp_path / "b"), params, st)
        _assert_trees_equal(pa, pb)
        _assert_trees_equal(sa, sb)


class TestLegacyMigration:
    def test_legacy_npz_restores_through_new_path(self, tmp_path):
        params, st = _state()
        base = str(tmp_path / "old")
        CKPT.save_npz(base, params, st, step=11, tokens_seen=2816.0)
        assert os.path.isfile(base + ".npz")     # true single-file layout
        p2, s2, meta = CKPT.restore(base, params, st)
        assert meta["step"] == 11
        assert meta["tokens_seen"] == 2816.0       # float preserved
        _assert_trees_equal(params, p2)
        _assert_trees_equal(st, s2)

    def test_trainer_resumes_from_pre_pr5_float_checkpoint(self,
                                                           tmp_path):
        """A mid-ramp checkpoint written by the pre-PR-5 writer (one
        .npz, float ``tokens_seen``) resumes through
        ``Trainer.restore_checkpoint`` and continues the uninterrupted
        trajectory bitwise."""
        cfg = _cfg(kind="seesaw")
        src = MarkovLM(128, seed=0)
        full = Trainer(cfg)
        full.run(PhaseDataLoader(src, full.plan, 32))

        mid = full.plan.steps_per_phase(32)[0] + 1
        tr = Trainer(cfg)
        tr.run(PhaseDataLoader(src, tr.plan, 32), max_steps=mid)
        path = str(tmp_path / "old.npz")
        # the exact pre-PR-5 on-disk state: float tokens_seen + the
        # phase metadata save_phase_checkpoint has always recorded
        ph = tr.plan.realized_phase_at(tr.state.tokens_seen, 32)
        CKPT.save_npz(path, tr.state.params, tr.state.opt_state,
                      tr.state.step, float(tr.state.tokens_seen),
                      extra={"phase": ph.index,
                             "batch_size": ph.batch_size,
                             "schedule_kind": tr.plan.kind,
                             "total_tokens": tr.plan.total_tokens})

        tr2 = Trainer(cfg)
        meta = tr2.restore_checkpoint(path)
        assert isinstance(tr2.state.tokens_seen, int)
        assert meta["phase"] == 1
        loader = PhaseDataLoader(src, tr2.plan, 32).resume(
            tr2.state.tokens_seen)
        tr2.run(loader)
        ref = full.history[mid:]
        assert len(tr2.history) == len(ref)
        for a, b in zip(ref, tr2.history):
            assert a["step"] == b["step"]
            assert a["lr"] == b["lr"]
            np.testing.assert_array_equal(a["loss"], b["loss"])
        _assert_trees_equal(full.state.params, tr2.state.params)

    def test_new_save_retires_legacy_file(self, tmp_path):
        """Re-saving over a legacy path replaces it with the sharded
        directory AND removes the stale .npz — otherwise a later save
        interrupted mid-write would leave restore silently falling
        back to a months-old checkpoint."""
        params, st = _state()
        base = str(tmp_path / "ck")
        CKPT.save_npz(base, params, st, step=1, tokens_seen=32.0)
        params2 = jax.tree.map(lambda x: x * 2, params)
        CKPT.save(base, params2, st, step=2, tokens_seen=64)
        assert not os.path.exists(base + ".npz")
        assert not os.path.exists(base + ".meta.json")
        p2, _, meta = CKPT.restore(base, params, st)
        assert meta["step"] == 2
        _assert_trees_equal(params2, p2)
        # an interrupted NEXT save (manifest invalidated, no commit)
        # must now fail loudly, not resurrect stale state
        os.remove(os.path.join(base, "manifest.json"))
        with pytest.raises(FileNotFoundError):
            CKPT.restore(base, params, st)


class TestChecksums:
    def test_manifest_carries_crc32_and_writer(self, tmp_path):
        params, st = _state()
        base = str(tmp_path / "ck")
        CKPT.save(base, params, st, step=1, tokens_seen=32)
        man = json.load(open(os.path.join(base, "manifest.json")))
        assert man["format"] == CKPT.FORMAT_VERSION
        for entry in man["arrays"].values():
            for sh in entry["shards"]:
                assert isinstance(sh["crc32"], int)
                assert sh["writer"] == 0        # single process
                # and the recorded crc really is the file's content crc
                assert CKPT._crc_of_file(
                    os.path.join(base, sh["file"])) == sh["crc32"]

    def test_corrupt_block_raises_naming_it(self, tmp_path):
        """Flipping bytes of ONE block file must fail verification with
        an error that names that block — and restore without
        ``verify`` must stay permissive (the fast path reads only what
        it needs and trusts the disk)."""
        params, st = _state()
        base = str(tmp_path / "ck")
        CKPT.save(base, params, st, step=1, tokens_seen=32)
        man = json.load(open(os.path.join(base, "manifest.json")))
        # pick a matrix leaf deterministically (largest block file)
        victim = max(
            (sh for e in man["arrays"].values() for sh in e["shards"]),
            key=lambda sh: os.path.getsize(
                os.path.join(base, sh["file"])))["file"]
        fpath = os.path.join(base, victim)
        arr = np.load(fpath)
        arr.reshape(-1)[:4] += 1.0
        np.save(fpath, arr)                # same shape/dtype, new bytes
        with pytest.raises(CKPT.CheckpointCorruptionError) as ei:
            CKPT.restore(base, params, st, verify=True)
        assert victim in str(ei.value)
        # unverified restore still works (returns the corrupt bytes)
        p_r, _, _ = CKPT.restore(base, params, st)
        assert p_r is not None

    def test_missing_block_raises_corruption_error(self, tmp_path):
        params, st = _state()
        base = str(tmp_path / "ck")
        CKPT.save(base, params, st, step=1, tokens_seen=32)
        man = json.load(open(os.path.join(base, "manifest.json")))
        victim = next(iter(man["arrays"].values()))["shards"][0]["file"]
        os.remove(os.path.join(base, victim))
        with pytest.raises(CKPT.CheckpointCorruptionError,
                           match="missing on disk"):
            CKPT.restore(base, params, st, verify=True)

    def test_legacy_npz_verify_warns_not_crashes(self, tmp_path):
        params, st = _state()
        base = str(tmp_path / "ck")
        CKPT.save_npz(base, params, st, step=1, tokens_seen=32.0)
        with pytest.warns(UserWarning, match="no.*checksums"):
            CKPT.restore(base, params, st, verify=True)


class TestExactTokens:
    def test_int_passthrough_silent(self):
        import warnings as W
        with W.catch_warnings():
            W.simplefilter("error")
            assert CKPT.exact_tokens(2816) == 2816
            assert CKPT.exact_tokens(2 ** 60 + 1) == 2 ** 60 + 1

    def test_integral_float_silent(self):
        import warnings as W
        with W.catch_warnings():
            W.simplefilter("error")
            assert CKPT.exact_tokens(2816.0) == 2816

    def test_non_integral_float_warns_and_rounds(self):
        with pytest.warns(UserWarning,
                          match="not exactly representable"):
            assert CKPT.exact_tokens(2816.3) == 2816

    def test_float_past_2_53_warns(self):
        with pytest.warns(UserWarning, match="2\\^53"):
            CKPT.exact_tokens(float(2 ** 54))
