"""Adaptive Seesaw on the fused engine: live plan extension
(``SeesawPlan.extend_at``), the device loss EMA, mid-stream
re-chunking, the compile-cache invariant under dynamically-created
phases, and bitwise checkpoint resume between cuts.

The run knobs (window=8, rel_threshold=2e-2, ema_decay=0.9, lr=1e-2)
are tuned so the tiny MarkovLM run fires three cuts inside ~160 steps
— a full 4→8→16→32 ramp — keeping every test on the fast tier.
"""
import math

import jax
import numpy as np
import pytest

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.core import seesaw as SS
from repro.core.adaptive import AdaptiveSeesaw
from repro.data import MarkovLM, PhaseDataLoader
from repro.train.trainer import Trainer

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                   n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                   vocab_size=128, max_seq_len=64, rope_theta=1e4)

SEQ, B0, STEPS = 32, 4, 360
KNOBS = dict(plateau_window=8, plateau_threshold=2e-2, ema_decay=0.9)


def _cfg(**kw):
    return RunConfig(model=TINY,
                     schedule=ScheduleConfig(kind="adaptive-seesaw",
                                             base_lr=1e-2,
                                             warmup_frac=0.02, alpha=2.0,
                                             n_cuts=4, **KNOBS),
                     optimizer=OptimizerConfig(kind="adamw"),
                     seq_len=SEQ, global_batch_size=B0,
                     total_tokens=SEQ * B0 * STEPS, remat=False,
                     log_every=1000, **kw)


def _run(fuse_steps, max_steps=None):
    tr = Trainer(_cfg(), fuse_steps=fuse_steps)
    loader = PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, SEQ)
    tr.run(loader, max_steps=max_steps)
    return tr


@pytest.fixture(scope="module")
def fused_run():
    return _run(fuse_steps=4)


# --------------------------------------------------------------------- #
# plan-level: extend_at and build_plan validation
# --------------------------------------------------------------------- #

class TestPlanExtension:
    def _plan(self, b0=4, total=SEQ * B0 * STEPS):
        return SS.build_plan(kind="adaptive-seesaw", base_lr=1e-2,
                             total_tokens=float(total), warmup_frac=0.02,
                             b0=b0, alpha=2.0)

    def test_adaptive_plan_starts_single_phase(self):
        p = self._plan()
        assert len(p.phases) == 1
        assert p.phases[0].batch_size == 4
        # per-cut factors stay on the Seesaw line: α_s√β = α
        assert p.alpha == pytest.approx(math.sqrt(2.0))
        assert p.beta == pytest.approx(2.0)

    def test_extend_appends_seesaw_phase(self):
        p = self._plan()
        cut = 80 * B0 * SEQ
        q = p.extend_at(cut, seq_len=SEQ)
        assert len(q.phases) == 2
        assert q.phases[0].end_tokens == float(cut)
        assert q.phases[1].start_tokens == float(cut)
        assert q.phases[1].end_tokens == p.total_tokens
        assert q.phases[1].batch_size == 8          # ×α batch
        assert q.phases[1].lr_scale == pytest.approx(
            1.0 / math.sqrt(2.0))                   # ÷√α LR
        # the original plan is untouched (frozen value semantics)
        assert len(p.phases) == 1

    def test_extend_chains(self):
        p = self._plan()
        q = p.extend_at(80 * B0 * SEQ, seq_len=SEQ)
        r = q.extend_at(80 * B0 * SEQ + 40 * 8 * SEQ, seq_len=SEQ)
        assert [ph.batch_size for ph in r.phases] == [4, 8, 16]
        assert r.phases[2].lr_scale == pytest.approx(0.5)

    def test_extend_off_step_boundary_raises(self):
        p = self._plan()
        with pytest.raises(ValueError, match="step boundary"):
            p.extend_at(80 * B0 * SEQ + 7, seq_len=SEQ)

    def test_extend_outside_last_phase_raises(self):
        p = self._plan()
        with pytest.raises(ValueError, match="outside"):
            p.extend_at(int(p.total_tokens) + B0 * SEQ, seq_len=SEQ)
        q = p.extend_at(80 * B0 * SEQ, seq_len=SEQ)
        with pytest.raises(ValueError, match="outside"):
            # inside an already-closed phase
            q.extend_at(40 * B0 * SEQ, seq_len=SEQ)

    def test_extend_clamps_to_max_batch(self):
        p = self._plan()
        q = p.extend_at(80 * B0 * SEQ, seq_len=SEQ, max_batch_size=6)
        assert q.phases[1].batch_size == 6
        # the LR still cuts even when the ramp saturates
        assert q.phases[1].lr_scale == pytest.approx(
            1.0 / math.sqrt(2.0))

    # -- build_plan validation (satellite bugfix regression) ------------ #
    @pytest.mark.parametrize("kind", ["step", "constant", "naive-ramp"])
    def test_malformed_cuts_raise_for_every_kind(self, kind):
        """Regression: .validate() used to run only for seesaw kinds,
        so 'step'/'constant'/'naive-ramp' built silently from cut
        lists that were out of order or past total_tokens."""
        kw = dict(kind=kind, base_lr=1.0, total_tokens=1e6,
                  warmup_frac=0.1, b0=8, alpha=2.0, beta=2.0)
        with pytest.raises(ValueError, match="increasing"):
            SS.build_plan(cut_tokens=[5e5, 3e5], **kw)
        with pytest.raises(ValueError, match="outside"):
            SS.build_plan(cut_tokens=[3e5, 2e6], **kw)
        with pytest.raises(ValueError, match="outside"):
            SS.build_plan(cut_tokens=[5e4], **kw)   # inside warmup

    def test_wellformed_cuts_still_build(self):
        p = SS.build_plan(kind="step", base_lr=1.0, total_tokens=1e6,
                          warmup_frac=0.1, b0=8, alpha=2.0,
                          cut_tokens=[3e5, 6e5])
        assert len(p.phases) == 3

    def test_steps_per_phase_is_authoritative(self):
        """Phase.n_steps is a per-phase estimate; the carry-aware
        steps_per_phase allocation is what the loader/engine run.
        They agree within one step per phase and exactly in total."""
        p = SS.build_plan(kind="seesaw", base_lr=1.0, total_tokens=1e6,
                          warmup_frac=0.1, b0=8, alpha=2.0, n_cuts=3)
        alloc = p.steps_per_phase(128)
        for ph, n in zip(p.phases, alloc):
            assert abs(ph.n_steps(128) - n) <= 1
        assert sum(alloc) == p.total_steps(128)


# --------------------------------------------------------------------- #
# engine-level: the live adaptive run
# --------------------------------------------------------------------- #

class TestAdaptiveEngineRun:
    def test_cuts_fire_and_ramp(self, fused_run):
        tr = fused_run
        assert tr.controller.n_cuts >= 2
        assert [p.batch_size for p in tr.plan.phases] == \
            [B0 * 2 ** i for i in range(tr.controller.n_cuts + 1)]
        # every cut landed on a chunk boundary (steps ≡ 0 mod K here:
        # re-chunking restarts the stream exactly at the cut step)
        assert all(s % 4 == 0 for s in tr.controller.cut_steps)
        # cut_tokens are the realized token counts at the cut steps
        toks = {h["step"]: h["tokens"] for h in tr.history}
        assert tr.cut_tokens == [toks[s] for s in tr.controller.cut_steps]

    def test_lr_cuts_by_sqrt_alpha_at_cut_steps(self, fused_run):
        tr = fused_run
        lr = {h["step"]: h["lr"] for h in tr.history}
        for i, s in enumerate(tr.controller.cut_steps):
            assert lr[s + 1] == pytest.approx(
                lr[s] / math.sqrt(2.0), rel=1e-5)

    def test_one_executable_per_distinct_batch_size(self, fused_run):
        """The compile-cache invariant survives dynamically-created
        phases: runtime LR tables mean a cut changes argument values,
        never programs."""
        tr = fused_run
        sizes = {h["batch_size"] for h in tr.history}
        assert len(tr._step_cache) == len(sizes) >= 3
        assert {k[0] for k in tr._step_cache} == sizes
        assert {k[2] for k in tr._step_cache} == {4}   # one chunk K

    def test_fused_matches_eager_cut_for_cut(self, fused_run):
        """K=1 and K=4 adaptive runs make identical cut decisions and
        train identically: the EMA recursion is chunking-independent
        and the plateau test runs at the same window boundaries."""
        eager = _run(fuse_steps=1)
        fused = fused_run
        assert eager.controller.cut_steps == fused.controller.cut_steps
        assert eager.cut_tokens == fused.cut_tokens
        for a, b in zip(jax.tree.leaves(eager.state.params),
                        jax.tree.leaves(fused.state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_device_ema_matches_host_recursion(self, fused_run):
        """The device-accumulated EMA is the exact f32 recursion over
        the per-step losses, and a host-side controller replaying that
        recursion at the same chunk boundaries fires cut-for-cut with
        the live run."""
        tr = fused_run
        losses = [np.float32(h["loss"]) for h in tr.history]
        decay = np.float32(KNOBS["ema_decay"])
        one = np.float32(1.0)
        ema = None
        ema_at = {}
        for i, l in enumerate(losses):
            ema = l if ema is None else np.float32(
                decay * ema + (one - decay) * l)
            ema_at[i + 1] = ema
        assert float(ema) == pytest.approx(tr.state.loss_ema, rel=1e-5)

        sch = tr.cfg.schedule
        ctl = AdaptiveSeesaw(alpha=sch.alpha,
                             window=sch.plateau_window,
                             rel_threshold=sch.plateau_threshold,
                             max_cuts=sch.n_cuts,
                             min_steps_between=sch.plateau_window)
        n_steps = len(losses)
        s = 0
        while s < n_steps:
            n = min(4, n_steps - s)
            s += n
            ctl.observe_smoothed(float(ema_at[s]), n)
        assert ctl.cut_steps == tr.controller.cut_steps


# --------------------------------------------------------------------- #
# checkpoint: bitwise resume between cuts
# --------------------------------------------------------------------- #

class TestAdaptiveCheckpoint:
    def test_resume_between_cuts_is_bitwise(self, fused_run, tmp_path):
        """Save between the first and second cut; a fresh trainer
        rebuilds the extended plan from the manifest's cut tokens,
        reloads the controller mid-window, re-fires the remaining cuts
        at identical steps and ends with bitwise-identical params."""
        ref = fused_run
        cuts = ref.controller.cut_steps
        assert len(cuts) >= 2
        mid = cuts[0] + 4 * ((cuts[1] - cuts[0]) // 8)  # chunk boundary
        assert cuts[0] < mid < cuts[1]

        part1 = _run(fuse_steps=4, max_steps=mid)
        assert part1.state.step == mid
        assert part1.controller.cut_steps == [cuts[0]]
        path = str(tmp_path / "adaptive-ckpt")
        part1.save_checkpoint(path)

        tr2 = Trainer(_cfg(), fuse_steps=4)
        meta = tr2.restore_checkpoint(path)
        assert meta["step"] == mid
        assert tr2.controller.cut_steps == [cuts[0]]
        assert tr2.controller.steps == mid
        assert [p.batch_size for p in tr2.plan.phases] == [4, 8]
        assert tr2.state.loss_ema == part1.state.loss_ema
        loader = PhaseDataLoader(MarkovLM(128, seed=0), tr2.plan, SEQ,
                                 validate=False)
        loader.resume(tr2.state.tokens_seen)
        tr2.run(loader)

        assert tr2.controller.cut_steps == cuts
        assert tr2.cut_tokens == ref.cut_tokens
        assert tr2.state.step == ref.state.step
        for a, b in zip(jax.tree.leaves(ref.state.params),
                        jax.tree.leaves(tr2.state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_prescheduled_checkpoint_rejected(self, tmp_path):
        """An adaptive trainer cannot resume a checkpoint that carries
        no controller state — fail with a clear error instead of
        restarting the controller from scratch mid-run."""
        cfg = RunConfig(model=TINY,
                        schedule=ScheduleConfig(kind="seesaw",
                                                base_lr=1e-3, alpha=2.0,
                                                n_cuts=2),
                        optimizer=OptimizerConfig(kind="adamw"),
                        seq_len=SEQ, global_batch_size=B0,
                        total_tokens=SEQ * B0 * 40, remat=False)
        tr = Trainer(cfg)
        loader = PhaseDataLoader(MarkovLM(128, seed=0), tr.plan, SEQ)
        tr.run(loader, max_steps=8)
        path = str(tmp_path / "sched-ckpt")
        tr.save_checkpoint(path)

        tr2 = Trainer(_cfg(), fuse_steps=4)
        with pytest.raises(ValueError, match="no adaptive"):
            tr2.restore_checkpoint(path)
