"""Serving subsystem tests: paged KV cache + continuous-batching engine.

Every full-model numeric claim is *bitwise* (``np.array_equal``), not
approximate: the paged xla decode path is built so masked positions
score exactly -1e30, exp underflows to exactly 0.0, and stale page
contents sit beyond the causal reach — so a paged lookup and a dense
cache must produce identical logits.  Coverage:

- ``PagePool`` allocator bookkeeping (LIFO reuse, null-page
  reservation, ``OutOfPages``, double-free, defrag remapping).
- Scatter/gather layout roundtrip through the fused head-interleaved
  pool, page sizes {1, 4, 16}.
- Ragged decode attention vs per-request dense ``chunked_attention``
  at page-count boundaries and GQA head ratios (the interpret-mode
  Pallas parity lives in tests/test_kernels.py).
- The typed-cache API: ``registry.prefill`` returns a
  ``DenseKVCache``, ``decode_step`` dispatches on the cache type and
  rejects raw pytrees.
- Full-model paged decode (``PagedKVCache`` through
  ``registry.decode_step``) vs solo dense prefill+decode.
- ``ServingEngine`` under directed admit/evict schedules — queueing,
  EOS eviction, staggered arrivals, mid-decode defrag, a pool small
  enough to serialize — always bitwise against the solo dense
  ``Server`` oracle, with pages drained and the executable budget
  held.
- The recurrent ("state") serving mode: an SSM engine against the
  dense Server oracle.
- The request API: rid assignment, results, detokenizer text,
  completion order, the ``generate()`` compat wrapper, and the
  ``Server.generate`` deprecation.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SSMConfig
from repro.kernels import backend as KB
from repro.models import registry as R
from repro.models.attention import chunked_attention
from repro.serving import (DenseKVCache, GenerationRequest, KVCache,
                           OutOfPages, ServingEngine, pow2_buckets)
from repro.serving import cache as SC
from repro.train.serve import Server

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAS_HYPOTHESIS = False


def tiny_cfg(n_heads=2, n_kv_heads=1, **kw):
    base = dict(name="serve-tiny", arch_type="dense", n_layers=2,
                d_model=32, n_heads=n_heads, n_kv_heads=n_kv_heads,
                head_dim=8, d_ff=64, vocab_size=64, max_seq_len=128,
                rope_theta=1e4)
    base.update(kw)
    return ModelConfig(**base)


CFG = tiny_cfg()
MAX_LEN = 32

SSM_CFG = ModelConfig(name="serve-ssm", arch_type="ssm", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=1, head_dim=8,
                      d_ff=64, vocab_size=64, max_seq_len=64,
                      rope_theta=1e4,
                      ssm=SSMConfig(d_state=16, d_conv=4, expand=2,
                                    head_dim=16, chunk_size=16))


@pytest.fixture(scope="module")
def params():
    return R.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def engine(params):
    """One shared engine — the compile cache is the expensive part, and
    reusing it across tests is itself part of the contract (reset()
    keeps executables)."""
    return ServingEngine(CFG, params, decode_slots=2, page_size=4,
                         max_len=MAX_LEN)


@pytest.fixture(scope="module")
def oracle(params, engine):
    """Solo dense Server sized to the engine's per-slot page window."""
    return Server(CFG, params,
                  max_len=engine.pages_per_slot * engine.page_size,
                  buckets=engine.buckets)


def solo(oracle, prompt, max_new, eos_id=None):
    """The oracle answer: one dense run of this request alone,
    truncated after the first EOS token."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        out = oracle.generate(np.asarray(prompt)[None], max_new)[0]
    if eos_id is not None:
        hits = np.flatnonzero(out == eos_id)
        if hits.size:
            out = out[:hits[0] + 1]
    return out


def run_engine(engine, reqs, max_steps=300):
    for r in reqs:
        engine.submit(r)
    engine.drain(max_steps=max_steps)
    return {r.rid: engine.result(r.rid).tokens for r in reqs}


def check_drained(engine):
    assert engine.done
    assert engine.pool.n_used == 0, "pages leaked after drain"
    assert engine._reserved == 0, "reservation leaked after drain"
    assert engine.executables <= engine.executable_budget, (
        f"{engine.executables} executables exceed budget "
        f"{engine.executable_budget}")


def prompts_rng(seed, sizes, vocab=CFG.vocab_size):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, (s,)).astype(np.int32)
            for s in sizes]


# --------------------------------------------------------------------- #
# allocator bookkeeping
# --------------------------------------------------------------------- #

class TestPagePool:
    def _pool(self, n_pages=8, page_size=4):
        return SC.PagePool(tiny_cfg(), n_pages, page_size)

    def test_null_page_never_allocated(self):
        pool = self._pool()
        got = pool.alloc(pool.capacity)
        assert SC.NULL_PAGE not in got
        assert sorted(got) == list(range(1, pool.n_pages))

    def test_lifo_reuse(self):
        pool = self._pool()
        a = pool.alloc(3)
        pool.free([a[-1]])
        assert pool.alloc(1) == [a[-1]]     # hot page comes back first

    def test_out_of_pages(self):
        pool = self._pool(n_pages=4)
        pool.alloc(3)
        with pytest.raises(OutOfPages):
            pool.alloc(1)

    def test_double_free_and_invalid_free(self):
        pool = self._pool()
        (p,) = pool.alloc(1)
        pool.free([p])
        with pytest.raises(ValueError):
            pool.free([p])
        with pytest.raises(ValueError):
            pool.free([SC.NULL_PAGE])
        with pytest.raises(ValueError):
            pool.free([pool.n_pages])

    def test_occupancy_accounting(self):
        pool = self._pool(n_pages=9)
        assert pool.capacity == 8 and pool.n_used == 0
        got = pool.alloc(4)
        assert pool.n_used == 4 and pool.occupancy() == 0.5
        pool.free(got)
        assert pool.n_used == 0 and pool.n_free == pool.capacity

    def test_pages_for(self):
        pool = self._pool(page_size=4)
        assert pool.pages_for(0) == 1       # a slot always owns a page
        assert pool.pages_for(4) == 1
        assert pool.pages_for(5) == 2
        assert pool.pages_for(8) == 2

    def test_invalid_pools_rejected(self):
        with pytest.raises(ValueError):
            SC.PagePool(tiny_cfg(), 1, 4)
        with pytest.raises(ValueError):
            SC.PagePool(tiny_cfg(), 4, 0)
        with pytest.raises(ValueError):
            SC.PagePool(tiny_cfg(), 4, 4, kind="bogus")
        with pytest.raises(ValueError):     # state pools are page_size 1
            SC.PagePool(SSM_CFG, 4, 4, kind="state")


# --------------------------------------------------------------------- #
# layout roundtrip
# --------------------------------------------------------------------- #

def test_interleave_roundtrip():
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(3, 5, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(3, 5, 2, 8)), jnp.float32)
    kv = SC.kv_interleave(k, v)
    assert kv.shape == (3, 5, 4, 8)
    # head h's K at 2h, V at 2h+1
    assert np.array_equal(np.asarray(kv[..., 0, :]),
                          np.asarray(k[..., 0, :]))
    assert np.array_equal(np.asarray(kv[..., 1, :]),
                          np.asarray(v[..., 0, :]))
    k2, v2 = SC.kv_deinterleave(kv)
    assert np.array_equal(np.asarray(k2), np.asarray(k))
    assert np.array_equal(np.asarray(v2), np.asarray(v))


@pytest.mark.parametrize("page_size", [1, 4, 16])
def test_scatter_gather_roundtrip(page_size):
    """Prompt K/V written through the pool and gathered back is bitwise
    the original for every row < length; bucket-padding rows land in the
    null page and touch no allocated page."""
    cfg = tiny_cfg()
    rng = np.random.default_rng(1)
    L, B, S = cfg.n_layers, 2, 19
    lengths = np.asarray([19, 7], np.int32)
    n_pages = 2 * B * -(-S // page_size) + 1
    pool = SC.PagePool(cfg, n_pages=n_pages, page_size=page_size,
                       dtype=jnp.float32)
    P = pool.pages_for(S)
    tables = [pool.alloc(P) for _ in range(B)]
    pages = jnp.asarray(tables, jnp.int32)
    k = jnp.asarray(rng.normal(size=(L, B, S, cfg.n_kv_heads,
                                     cfg.head_dim)), jnp.float32)
    v = jnp.asarray(rng.normal(size=k.shape), jnp.float32)
    kv = SC.scatter_prefill(pool.kv, k, v, pages,
                            jnp.asarray(lengths), page_size=page_size)
    for layer in range(L):
        gk, gv = SC.gather_pages(kv[layer], pages, page_size=page_size)
        for b in range(B):
            n = lengths[b]
            assert np.array_equal(np.asarray(gk[b, :n]),
                                  np.asarray(k[layer, b, :n]))
            assert np.array_equal(np.asarray(gv[b, :n]),
                                  np.asarray(v[layer, b, :n]))
    # rows past each request's length went to the null page, not into
    # any allocated page: request 1's pages hold zeros beyond row 7
    off = int(lengths[1])
    flat = np.asarray(kv[0][jnp.asarray(tables[1])]).reshape(
        P * page_size, -1)
    assert flat[:off].any()
    assert np.all(flat[off:] == 0.0)


# --------------------------------------------------------------------- #
# ragged attention vs dense oracle — bitwise
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("H,Hkv", [(2, 2), (4, 2), (4, 1)])
def test_ragged_attention_bitwise_vs_dense(H, Hkv):
    """Batched per-request lookup == per-request scalar dense attention,
    bitwise, at ragged depths including page boundaries."""
    rng = np.random.default_rng(2)
    B, hd, Skv = 4, 16, 33
    # positions: 0 (first decode), exact page fills for ps in {1,4,16},
    # and one mid-page
    lengths = np.asarray([0, 4, 16, 31], np.int32)
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, hd)), jnp.float32)
    out = KB.paged_decode_attention(q, k, v, jnp.asarray(lengths),
                                    backend="xla")
    for b in range(B):
        n = int(lengths[b])
        ref = chunked_attention(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                                causal=True, q_offset=n, kv_len=n + 1,
                                chunk=4096)
        assert np.array_equal(np.asarray(ref[0]), np.asarray(out[b]))


def test_ragged_attention_ignores_stale_tail():
    """Garbage beyond lengths[b] — stale page contents — cannot change
    the result: zeroing the tail gives bitwise-identical output."""
    rng = np.random.default_rng(3)
    B, H, Hkv, hd, Skv = 2, 2, 1, 8, 24
    lengths = jnp.asarray([5, 11], jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=k.shape), jnp.float32)
    mask = (jnp.arange(Skv)[None, :, None, None]
            <= lengths[:, None, None, None])
    a = KB.paged_decode_attention(q, k, v, lengths, backend="xla")
    b = KB.paged_decode_attention(q, jnp.where(mask, k, 0.0),
                                  jnp.where(mask, v, 0.0), lengths,
                                  backend="xla")
    assert np.array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------- #
# the typed-cache API
# --------------------------------------------------------------------- #

def test_prefill_returns_typed_cache(params):
    toks = jnp.asarray(prompts_rng(8, [6, 6]), jnp.int32)
    logits, cache = R.prefill(params, CFG, toks, cache_len_cap=16)
    assert isinstance(cache, DenseKVCache)
    assert isinstance(cache, KVCache)       # the protocol
    assert np.asarray(cache.lengths).tolist() == [6, 6]
    logits, cache = R.decode_step(
        params, CFG, cache,
        jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32))
    assert isinstance(cache, DenseKVCache)
    assert np.asarray(cache.lengths).tolist() == [7, 7]


def test_decode_step_rejects_raw_cache(params):
    tok = jnp.zeros((1, 1), jnp.int32)
    with pytest.raises(TypeError):
        R.decode_step(params, CFG, {"k": None, "v": None}, tok)


def test_paged_cache_is_pytree():
    """Static fields (page_size, kind) key executables; array fields
    flow through tree ops."""
    c = SC.PagedKVCache(kv=jnp.zeros((1, 2, 4, 2, 8)),
                        pages=jnp.zeros((1, 2), jnp.int32),
                        lengths=jnp.zeros((1,), jnp.int32),
                        page_size=4, kind="attn")
    leaves, treedef = jax.tree.flatten(c)
    assert len(leaves) == 3
    c2 = jax.tree.unflatten(treedef, leaves)
    assert c2.page_size == 4 and c2.kind == "attn"
    assert isinstance(c2, KVCache)


# --------------------------------------------------------------------- #
# full-model step parity — paged vs solo dense, bitwise logits
# --------------------------------------------------------------------- #

def _dense_solo_logits(cfg, params, prompt, n_steps, cap, dtype):
    """Per-request dense oracle: exact-length prefill + decode_step."""
    toks = jnp.asarray(prompt[None], jnp.int32)
    logits, cache = R.prefill(params, cfg, toks, cache_len_cap=cap,
                              dtype=dtype)
    outs = [np.asarray(logits[:, -1], np.float32)]
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(n_steps):
        logits, cache = R.decode_step(params, cfg, cache, tok,
                                      dtype=dtype)
        outs.append(np.asarray(logits[:, -1], np.float32))
        tok = jnp.argmax(logits[:, -1],
                         axis=-1)[:, None].astype(jnp.int32)
    return outs


@pytest.mark.parametrize("page_size,H,Hkv", [
    (1, 4, 2),          # one token per page: growth every step
    (4, 4, 2),
    (16, 4, 2),
    (4, 2, 2),          # MHA
    (4, 4, 1),          # maximal GQA fold
])
def test_paged_step_bitwise_vs_dense(page_size, H, Hkv):
    """The paged decode step at ragged depths reproduces solo dense runs
    bitwise.  Lengths are chosen so one request exactly fills its last
    page at prefill and another crosses into a fresh page mid-decode."""
    cfg = tiny_cfg(n_heads=H, n_kv_heads=Hkv)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    dtype = jnp.bfloat16
    n_steps = max(page_size + 1, 4)     # guarantees a page crossing
    # request 0 exactly fills pages at prefill; request 1 is one short
    # of a boundary, so its first decode write opens a fresh page
    s0 = 2 * page_size
    s1 = max(2 * page_size - 1, 1)
    prompts = [rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (s0, s1)]
    B = len(prompts)
    smax = max(s0, s1)

    pool = SC.PagePool(cfg, n_pages=64, page_size=page_size, dtype=dtype)
    per_req = pool.pages_for(smax + n_steps)
    tables = [pool.alloc(per_req) for _ in range(B)]
    pages = jnp.asarray(tables, jnp.int32)
    lengths = np.asarray([s0, s1], np.int32)
    padded = np.zeros((B, smax), np.int32)
    for b, p in enumerate(prompts):
        padded[b, :len(p)] = p

    logits, k, v = R.prefill_ragged(params, cfg, jnp.asarray(padded),
                                    jnp.asarray(lengths), dtype=dtype)
    pool_kv = SC.scatter_prefill(pool.kv, k, v, pages,
                                 jnp.asarray(lengths),
                                 page_size=page_size)
    paged = [np.asarray(logits[:, -1], np.float32)]
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    ln = jnp.asarray(lengths)

    def step_body(pkv, lg, t):
        cache = SC.PagedKVCache(kv=pkv, pages=pages, lengths=lg,
                                page_size=page_size, kind="attn")
        lgts, new = R.decode_step(params, cfg, cache, t, dtype=dtype)
        return lgts, new.kv, new.lengths

    step = jax.jit(step_body)
    for _ in range(n_steps):
        logits, pool_kv, ln = step(pool_kv, ln, tok)
        paged.append(np.asarray(logits[:, -1], np.float32))
        tok = jnp.argmax(logits[:, -1],
                         axis=-1)[:, None].astype(jnp.int32)

    cap = smax + n_steps + 1
    for b, prompt in enumerate(prompts):
        dense = _dense_solo_logits(cfg, params, prompt, n_steps, cap,
                                   dtype)
        for t, (d, p) in enumerate(zip(dense, paged)):
            assert np.array_equal(d[0], p[b]), \
                f"req {b} step {t}: paged logits diverge from dense"


def test_prefill_ragged_bitwise_vs_dense():
    """Bucket-padded ragged prefill == exact-length prefill: same last
    real-token logits, same K/V rows, bitwise."""
    cfg = tiny_cfg()
    params = R.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(6)
    S, bucket = 11, 16
    toks = rng.integers(0, cfg.vocab_size, (2, S)).astype(np.int32)
    ref_lg, cache = R.prefill(params, cfg, jnp.asarray(toks),
                              cache_len_cap=32)
    padded = jnp.pad(jnp.asarray(toks), ((0, 0), (0, bucket - S)))
    rag_lg, k, v = R.prefill_ragged(params, cfg, padded,
                                    jnp.full((2,), S, jnp.int32))
    assert np.array_equal(np.asarray(ref_lg), np.asarray(rag_lg))
    assert np.array_equal(
        np.asarray(k[:, :, :S]),
        np.asarray(cache.data["k"][:, :, :S].astype(k.dtype)))
    assert np.array_equal(
        np.asarray(v[:, :, :S]),
        np.asarray(cache.data["v"][:, :, :S].astype(v.dtype)))


def test_prefill_ragged_unsupported_family():
    assert not R.supports_paged(SSM_CFG)
    with pytest.raises(NotImplementedError):
        R.prefill_ragged(None, SSM_CFG, None, None)


# --------------------------------------------------------------------- #
# defrag
# --------------------------------------------------------------------- #

def test_defrag_preserves_gathered_kv():
    """Fragment the pool (free an interleaved table), defrag, and check
    the surviving request's gathered K/V is bitwise unchanged while its
    table is compacted to the low ids."""
    cfg = tiny_cfg()
    rng = np.random.default_rng(7)
    page_size, S = 4, 12
    pool = SC.PagePool(cfg, n_pages=16, page_size=page_size,
                       dtype=jnp.float32)
    P = pool.pages_for(S)
    t0, t1 = pool.alloc(P), pool.alloc(P)
    k = jnp.asarray(rng.normal(size=(cfg.n_layers, 2, S, cfg.n_kv_heads,
                                     cfg.head_dim)), jnp.float32)
    v = jnp.asarray(rng.normal(size=k.shape), jnp.float32)
    pool.kv = SC.scatter_prefill(pool.kv, k, v,
                                 jnp.asarray([t0, t1], jnp.int32),
                                 jnp.full((2,), S, jnp.int32),
                                 page_size=page_size)
    before = SC.gather_pages(pool.kv[0], jnp.asarray([t1], jnp.int32),
                             page_size=page_size)
    pool.free(t0)                        # fragment: low ids now free
    pool.defrag([t1])
    assert t1 == list(range(1, 1 + P))   # compacted in place
    after = SC.gather_pages(pool.kv[0], jnp.asarray([t1], jnp.int32),
                            page_size=page_size)
    assert np.array_equal(np.asarray(before[0]), np.asarray(after[0]))
    assert np.array_equal(np.asarray(before[1]), np.asarray(after[1]))
    assert pool.n_used == P
    # freed ids are reusable immediately after compaction
    assert pool.alloc(pool.n_free)


def test_defrag_rejects_duplicate_tables():
    pool = SC.PagePool(tiny_cfg(), 8, 4)
    t = pool.alloc(2)
    with pytest.raises(ValueError):
        pool.defrag([t, t])


# --------------------------------------------------------------------- #
# engine vs the solo dense oracle — directed schedules
# --------------------------------------------------------------------- #

def test_engine_matches_solo_oracle(engine, oracle):
    """5 ragged requests through 2 slots: forced queueing and page
    reuse across waves; every request bitwise equals its solo run."""
    engine.reset()
    sizes = [3, 16, 7, 1, 12]
    news = [6, 4, 8, 3, 5]
    reqs = [GenerationRequest(rid=i, prompt=p, max_new_tokens=n)
            for i, (p, n) in enumerate(zip(prompts_rng(0, sizes), news))]
    got = run_engine(engine, reqs)
    for r in reqs:
        want = solo(oracle, r.prompt, r.max_new_tokens)
        assert np.array_equal(got[r.rid], want), f"request {r.rid}"
    check_drained(engine)


def test_eos_eviction_frees_pages(engine, oracle):
    """EOS mid-stream: pick each request's own 2nd generated token as
    its eos_id, so the engine must cut generation early, evict, and
    free pages while other slots keep decoding."""
    engine.reset()
    prompts = prompts_rng(1, [5, 9, 14])
    eos = [int(solo(oracle, p, 8)[2]) for p in prompts]
    reqs = [GenerationRequest(rid=i, prompt=p, max_new_tokens=8,
                              eos_id=e)
            for i, (p, e) in enumerate(zip(prompts, eos))]
    got = run_engine(engine, reqs)
    for r in reqs:
        want = solo(oracle, r.prompt, r.max_new_tokens, eos_id=r.eos_id)
        assert np.array_equal(got[r.rid], want)
        assert len(got[r.rid]) <= 3          # actually truncated
        assert engine.result(r.rid).finish_reason == "eos"
    check_drained(engine)


def test_executable_invariant_across_schedules(engine, oracle):
    """Prompt lengths within one bucket share one prefill executable;
    the decode executable count stays 1 across occupancy patterns."""
    engine.reset()
    n0 = engine.n_prefill_executables
    # lengths 2..13 all fall in the 16-bucket
    reqs = [GenerationRequest(rid=i, prompt=p, max_new_tokens=2)
            for i, p in enumerate(prompts_rng(2, [2, 5, 9, 13]))]
    run_engine(engine, reqs)
    assert engine.n_prefill_executables - n0 <= 1
    assert engine.n_decode_executables == 1
    seen = engine.executables
    # a second wave with the same buckets compiles nothing new
    reqs = [GenerationRequest(rid=10 + i, prompt=p, max_new_tokens=3)
            for i, p in enumerate(prompts_rng(3, [4, 11]))]
    run_engine(engine, reqs)
    assert engine.executables == seen
    check_drained(engine)


def test_tiny_pool_serializes_head_of_line(params, oracle):
    """A pool that fits exactly one worst-case request: admission
    serializes, nothing deadlocks, results still match solo runs."""
    # capacity 4 pages == the largest request's worst-case demand
    # (pages_for(10 + 4 - 1) == 4), so admissions serialize
    eng = ServingEngine(CFG, params, decode_slots=2, page_size=4,
                        max_len=MAX_LEN, n_pages=5)
    sizes = [10, 6, 3]
    reqs = [GenerationRequest(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts_rng(4, sizes))]
    got = run_engine(eng, reqs)
    for r in reqs:
        assert np.array_equal(got[r.rid], solo(oracle, r.prompt, 4))
    assert eng.n_active == 0 and eng.pool.n_used == 0
    # serialization really happened: never more than one slot active
    assert eng.mean_occupancy() <= 0.5 + 1e-9


def test_defrag_mid_decode_is_transparent(engine, oracle):
    """Compacting the pool between steps must not change any output."""
    engine.reset()
    reqs = [GenerationRequest(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts_rng(5, [8, 13, 5]))]
    for r in reqs:
        engine.submit(r)
    n = 0
    while not engine.done:
        engine.step()
        engine.defrag()                      # every step, mid-stream
        n += 1
        assert n < 200
    for r in reqs:
        want = solo(oracle, r.prompt, r.max_new_tokens)
        assert np.array_equal(engine.result(r.rid).tokens, want)
    check_drained(engine)


def test_staggered_arrivals(engine, oracle):
    """Requests arriving while others are mid-decode join cleanly."""
    engine.reset()
    prompts = prompts_rng(6, [6, 11, 4, 9])
    arrive = [0, 0, 2, 5]
    reqs = [GenerationRequest(rid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    t, queued = 0, 0
    while queued < len(reqs) or not engine.done:
        while queued < len(reqs) and arrive[queued] <= t:
            engine.submit(reqs[queued])
            queued += 1
        engine.step()
        t += 1
        assert t < 200
    for r in reqs:
        assert np.array_equal(engine.result(r.rid).tokens,
                              solo(oracle, r.prompt, r.max_new_tokens))
    check_drained(engine)


def test_streaming_events_match_results(engine):
    """The (rid, token, finished) stream concatenates to exactly the
    finished results, finished flagged on the last token only."""
    engine.reset()
    reqs = [GenerationRequest(rid=i, prompt=p, max_new_tokens=n)
            for i, (p, n) in enumerate(zip(prompts_rng(9, [4, 8]),
                                           [3, 5]))]
    for r in reqs:
        engine.submit(r)
    streamed = {r.rid: [] for r in reqs}
    while not engine.done:
        for rid, tok, fin in engine.step():
            streamed[rid].append(tok)
            if fin:
                assert engine.result(rid) is not None
    for r in reqs:
        assert streamed[r.rid] == engine.result(r.rid).tokens.tolist()
    check_drained(engine)


# --------------------------------------------------------------------- #
# the recurrent ("state") serving mode
# --------------------------------------------------------------------- #

def test_state_mode_engine_matches_dense_oracle():
    """An SSM engine — one state page per request behind the same
    admission machinery — bitwise against the dense Server oracle."""
    params = R.init_params(jax.random.PRNGKey(2), SSM_CFG)
    eng = ServingEngine(SSM_CFG, params, decode_slots=2, max_len=MAX_LEN)
    assert eng.mode == "state"
    assert eng.page_size == 1 and eng.pages_per_slot == 1
    srv = Server(SSM_CFG, params, max_len=MAX_LEN)
    assert not srv.bucketed                  # ssm keeps exact-length
    sizes, news = [3, 9, 6], [5, 3, 4]
    reqs = [GenerationRequest(rid=i, prompt=p, max_new_tokens=n)
            for i, (p, n) in enumerate(zip(prompts_rng(7, sizes), news))]
    got = run_engine(eng, reqs)
    for r in reqs:
        want = solo(srv, r.prompt, r.max_new_tokens)
        assert np.array_equal(got[r.rid], want), f"request {r.rid}"
    # exact-length prefill: one executable per distinct prompt length
    assert eng.n_prefill_executables == len(set(sizes))
    assert eng.n_decode_executables == 1
    check_drained(eng)


# --------------------------------------------------------------------- #
# the request API
# --------------------------------------------------------------------- #

def test_rid_assignment(params):
    eng = ServingEngine(CFG, params, decode_slots=2, page_size=4,
                        max_len=MAX_LEN)
    p = prompts_rng(8, [4])[0]
    assert eng.submit(GenerationRequest(prompt=p,
                                        max_new_tokens=1)) == 0
    assert eng.submit(GenerationRequest(prompt=p,
                                        max_new_tokens=1)) == 1
    assert eng.submit(GenerationRequest(prompt=p, max_new_tokens=1,
                                        rid=10)) == 10
    with pytest.raises(ValueError):          # duplicate live rid
        eng.submit(GenerationRequest(prompt=p, max_new_tokens=1,
                                     rid=10))
    # explicit rids bump the auto counter past themselves
    assert eng.submit(GenerationRequest(prompt=p,
                                        max_new_tokens=1)) == 11


def test_submit_validation(engine, params):
    engine.reset()
    with pytest.raises(ValueError):
        engine.submit(GenerationRequest(prompt=np.zeros(0, np.int32),
                                        max_new_tokens=1))
    with pytest.raises(ValueError):          # 30 + 8 > max_len 32
        engine.submit(GenerationRequest(prompt=np.zeros(30, np.int32),
                                        max_new_tokens=8))
    with pytest.raises(NotImplementedError):
        # ring-cache sliding window: dense Server only
        ServingEngine(tiny_cfg(sliding_window=8), params=None)


def test_completion_order_and_drain(engine):
    """drain() returns results completed since the last drain, in
    completion order — the short request lands first even though it was
    submitted second."""
    engine.reset()
    p = prompts_rng(10, [5, 5])
    engine.submit(GenerationRequest(rid=0, prompt=p[0],
                                    max_new_tokens=6))
    engine.submit(GenerationRequest(rid=1, prompt=p[1],
                                    max_new_tokens=2))
    done = engine.drain(max_steps=50)
    assert [r.rid for r in done] == [1, 0]
    assert done[0].finish_reason == "length"
    assert done[0].prompt_len == 5
    assert engine.drain(max_steps=1) == []   # already drained


def test_detokenizer_text(params):
    eng = ServingEngine(CFG, params, decode_slots=2, page_size=4,
                        max_len=MAX_LEN,
                        detokenizer=lambda ids: " ".join(
                            f"<{t}>" for t in ids))
    rid = eng.submit(GenerationRequest(prompt=prompts_rng(11, [4])[0],
                                       max_new_tokens=3))
    (res,) = eng.drain(max_steps=20)
    assert res.rid == rid
    assert res.text == " ".join(f"<{t}>" for t in res.tokens)


def test_generate_wrapper_matches_server(engine, oracle):
    """The submit/drain compat wrapper reproduces the blocking greedy
    Server on a uniform batch."""
    engine.reset()
    batch = np.stack(prompts_rng(12, [9, 9]))
    got = engine.generate(batch, 4)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        want = oracle.generate(batch, 4)
    assert np.array_equal(got, want)
    check_drained(engine)


def test_server_generate_deprecated(oracle):
    with pytest.warns(DeprecationWarning, match="ServingEngine"):
        oracle.generate(np.zeros((1, 4), np.int32), 1)


# --------------------------------------------------------------------- #
# legacy Server recompile regression (satellite fix)
# --------------------------------------------------------------------- #

def test_server_bucketed_prefill_single_executable(params):
    """Two prompt lengths in the same bucket -> ONE prefill executable,
    and the outputs still match a manual unbucketed prefill+decode
    loop.  This is the fix for the unbounded per-(batch, prompt-len)
    recompile in the old Server."""
    srv = Server(CFG, params, max_len=64)
    outs = {}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for S in (8, 11):                    # same 16-bucket
            toks = prompts_rng(13, [S, S])
            outs[S] = srv.generate(np.stack(toks), 4)
        assert srv.bucketed
        assert srv.n_prefill_executables == 1
        srv.generate(np.stack(prompts_rng(14, [20, 20])), 2)  # 32-bucket
        assert srv.n_prefill_executables == 2

    # parity with a manual unbucketed run through the typed-cache API
    for S, got in outs.items():
        toks = jnp.asarray(np.stack(prompts_rng(13, [S, S])), jnp.int32)
        logits, cache = R.prefill(params, CFG, toks, cache_len_cap=64)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        want = [np.asarray(tok)]
        for _ in range(3):
            logits, cache = R.decode_step(params, CFG, cache, tok)
            tok = jnp.argmax(logits[:, -1],
                             -1)[:, None].astype(jnp.int32)
            want.append(np.asarray(tok))
        assert np.array_equal(got, np.concatenate(want, axis=1))


# --------------------------------------------------------------------- #
# randomized schedules (hypothesis; skipped when not installed)
# --------------------------------------------------------------------- #

if HAS_HYPOTHESIS:
    SCHEDULES = st.lists(
        st.tuples(st.integers(1, 16),        # prompt length
                  st.integers(1, 6),         # max_new
                  st.integers(0, 8),         # arrival step
                  st.booleans()),            # cut at an observed token?
        min_size=1, max_size=5)

    @settings(max_examples=8, deadline=None)
    @given(sched=SCHEDULES, seed=st.integers(0, 2 ** 16))
    def test_random_schedules_match_solo_runs(engine, oracle, sched,
                                              seed):
        """Random arrival/EOS schedules: every request equals its solo
        dense run, pages drain to zero, executables stay bounded."""
        engine.reset()
        rng = np.random.default_rng(seed)
        reqs = []
        for i, (S, n, at, cut) in enumerate(sched):
            p = rng.integers(0, CFG.vocab_size, (S,)).astype(np.int32)
            eos = None
            if cut and n >= 2:
                eos = int(solo(oracle, p, n)[n // 2])
            reqs.append((at, GenerationRequest(
                rid=i, prompt=p, max_new_tokens=n, eos_id=eos)))
        reqs.sort(key=lambda x: x[0])
        t, q = 0, 0
        while q < len(reqs) or not engine.done:
            while q < len(reqs) and reqs[q][0] <= t:
                engine.submit(reqs[q][1])
                q += 1
            engine.step()
            t += 1
            assert t < 400
        for _, r in reqs:
            want = solo(oracle, r.prompt, r.max_new_tokens,
                        eos_id=r.eos_id)
            assert np.array_equal(engine.result(r.rid).tokens, want)
        check_drained(engine)
else:                                         # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed (dev extra)")
    def test_random_schedules_match_solo_runs():
        pass
