import numpy as np
import pytest

from repro.core.seesaw import build_plan
from repro.data import LinearRegressionSampler, MarkovLM, PhaseDataLoader
from repro.core import theory as T


class TestMarkovLM:
    def test_deterministic_per_step(self):
        src = MarkovLM(vocab_size=128, seed=3)
        a = src.sample(5, 4, 32)
        b = src.sample(5, 4, 32)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_different_steps_differ(self):
        src = MarkovLM(vocab_size=128, seed=3)
        a = src.sample(1, 4, 32)["tokens"]
        b = src.sample(2, 4, 32)["tokens"]
        assert not np.array_equal(a, b)

    def test_labels_are_shifted_tokens(self):
        src = MarkovLM(vocab_size=128, seed=0)
        d = src.sample(0, 2, 16)
        np.testing.assert_array_equal(d["tokens"][:, 1:],
                                      d["labels"][:, :-1])

    def test_transitions_follow_table(self):
        src = MarkovLM(vocab_size=64, branching=4, seed=1)
        d = src.sample(0, 8, 64)
        toks, labs = d["tokens"], d["labels"]
        for b in range(8):
            for t in range(63):
                assert labs[b, t] in src.table[toks[b, t]]

    def test_entropy_floor_positive(self):
        src = MarkovLM(vocab_size=128, branching=8)
        h = src.conditional_entropy()
        assert 0.0 < h < np.log(8) + 1e-9


class TestLoader:
    def test_batch_ramp_shapes(self):
        plan = build_plan(kind="seesaw", base_lr=1.0,
                          total_tokens=64 * 8 * 64, warmup_frac=0.1,
                          b0=8, alpha=2.0, n_cuts=2)
        src = MarkovLM(vocab_size=64, seed=0)
        loader = PhaseDataLoader(src, plan, seq_len=64)
        seen = {}
        for phase, s, batch in loader:
            seen.setdefault(phase.batch_size, 0)
            seen[phase.batch_size] += 1
            assert batch["tokens"].shape == (phase.batch_size, 64)
        assert sorted(seen) == [8, 16, 32]

    def test_equal_token_data_order(self):
        """Cosine (constant B) and Seesaw (ramped B) consume identical
        sequences in identical order — sequence i is the same sample."""
        total = 64 * 8 * 32
        src = MarkovLM(vocab_size=64, seed=0)
        p1 = build_plan(kind="cosine", base_lr=1.0, total_tokens=total,
                        warmup_frac=0.1, b0=8, alpha=2.0, n_cuts=2)
        p2 = build_plan(kind="seesaw", base_lr=1.0, total_tokens=total,
                        warmup_frac=0.1, b0=8, alpha=2.0, n_cuts=2)
        stream1, stream2 = [], []
        for _, _, b in PhaseDataLoader(src, p1, 64):
            stream1.append(np.asarray(b["tokens"]))
        for _, _, b in PhaseDataLoader(src, p2, 64):
            stream2.append(np.asarray(b["tokens"]))
        s1 = np.concatenate(stream1)[:, 0]
        s2 = np.concatenate(stream2)[:, 0]
        n = min(len(s1), len(s2))
        np.testing.assert_array_equal(s1[:n], s2[:n])


class TestLinearRegression:
    def test_covariance_matches_spectrum(self):
        lam = T.power_law_spectrum(16, a=1.0)
        s = LinearRegressionSampler(lam, sigma2=0.5, seed=0)
        xs = np.concatenate([s.sample(i, 512)[0] for i in range(40)])
        emp = (xs * xs).mean(axis=0)
        np.testing.assert_allclose(emp, lam, rtol=0.15)

    def test_risk_at_optimum_is_noise_floor(self):
        lam = T.power_law_spectrum(8)
        s = LinearRegressionSampler(lam, sigma2=2.0)
        assert s.risk(s.w_star) == pytest.approx(1.0)
