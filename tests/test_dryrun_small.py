"""Distribution tests: the exact dry-run machinery (build_workload →
jit(in_shardings).lower().compile()) on an 8-device host mesh, run in a
subprocess so the main test process keeps its single-device world."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
import jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.launch import steps as ST
from repro.launch.mesh import make_test_mesh

arch, mode, multipod = sys.argv[1], sys.argv[2], sys.argv[3] == "1"
cfg = get_config(arch).reduced()
shape = {
    "train":   InputShape("t", 64, 8, "train"),
    "prefill": InputShape("p", 64, 8, "prefill"),
    "decode":  InputShape("d", 64, 8, "decode"),
}[mode]
mesh = make_test_mesh(2, 2, multi_pod=multipod)
fn, args, in_specs, out_specs = ST.build_workload(
    cfg, shape, multi_pod=multipod)
with mesh:
    in_sh = ST._named(mesh, in_specs)
    out_sh = ST._named(mesh, out_specs)
    lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
    compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):      # older jax: one dict per device
    ca = ca[0] if ca else {}
print(json.dumps({"ok": True, "flops": float(ca.get("flops", -1))}))
"""


def _run(arch, mode, multipod=False):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    # forced host devices are a CPU feature; without the pin jax
    # probes for a TPU backend ~5 min per subprocess on this image
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch, mode, "1" if multipod else "0"],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
    return rec


@pytest.mark.parametrize("arch", ["llama3.2-3b", "phi3.5-moe-42b-a6.6b",
                                  "mamba2-2.7b", "recurrentgemma-9b",
                                  "seamless-m4t-medium"])
def test_train_lowers_and_compiles(arch):
    _run(arch, "train")


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-2.7b"])
def test_decode_lowers_and_compiles(arch):
    _run(arch, "decode")


def test_prefill_lowers_and_compiles():
    _run("starcoder2-3b", "prefill")


def test_multipod_mesh_lowers():
    _run("llama3.2-3b", "train", multipod=True)


def test_shape_support_table():
    """long_500k is only for sub-quadratic archs (DESIGN.md §6)."""
    from repro.configs import INPUT_SHAPES, get_config
    from repro.launch.steps import shape_supported
    long = INPUT_SHAPES["long_500k"]
    ok, _ = shape_supported(get_config("mamba2-2.7b"), long)
    assert ok
    ok, why = shape_supported(get_config("yi-34b"), long)
    assert not ok and "full-attention" in why
    ok, _ = shape_supported(get_config("starcoder2-3b"), long)
    assert ok  # native sliding window
