"""Appendix C (Figure 4 / Table 3): Seesaw still matches cosine when
AdamW weight decay is enabled — reduced-scale LM, λ=1e-4 (the paper's
optimal from its sweep)."""
from __future__ import annotations

import time

import numpy as np

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.data import MarkovLM, PhaseDataLoader
from repro.train.trainer import Trainer

MODEL = ModelConfig(name="fig4-lm", arch_type="dense", n_layers=2,
                    d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                    d_ff=256, vocab_size=512, max_seq_len=64,
                    rope_theta=1e4)


def _train(kind: str, wd: float, steps: int = 120):
    cfg = RunConfig(model=MODEL,
                    schedule=ScheduleConfig(kind=kind, base_lr=3e-3,
                                            alpha=2.0, n_cuts=4),
                    optimizer=OptimizerConfig(kind="adamw",
                                              weight_decay=wd),
                    seq_len=64, global_batch_size=8,
                    total_tokens=64 * 8 * steps, remat=False)
    tr = Trainer(cfg)
    return tr.run(PhaseDataLoader(MarkovLM(512, seed=0), tr.plan, 64))


def run():
    rows = []
    t0 = time.time()
    wd = 1e-4
    h_cos = _train("cosine", wd)
    h_see = _train("seesaw", wd)
    us = (time.time() - t0) * 1e6 / (len(h_cos) + len(h_see))
    lc = float(np.mean([h["loss"] for h in h_cos[-5:]]))
    ls = float(np.mean([h["loss"] for h in h_see[-5:]]))
    rows.append(("figure4/wd1e-4_cosine_loss", us, f"{lc:.4f}"))
    rows.append(("figure4/wd1e-4_seesaw_loss", us, f"{ls:.4f}"))
    rows.append(("figure4/wd1e-4_gap", us, f"{abs(lc-ls):.4f}"))
    rows.append(("figure4/wd_robust", us, str(abs(lc - ls) < 0.12)))
    return rows
