"""Lemma 1: the 2/π serial-step limit — theoretical value, discrete-plan
convergence, and the measured reduction of real SeesawPlans."""
from __future__ import annotations

import math
import time

from repro.core.seesaw import (build_plan, continuous_step_fraction,
                               measured_speedup, theoretical_speedup)


def run():
    rows = []
    t0 = time.time()
    rows.append(("lemma1/theoretical_speedup", 0.1,
                 f"{theoretical_speedup():.4f}"))
    for n_cuts, alpha in [(4, 2.0), (12, 1.5), (30, 1.1), (60, 1.05)]:
        frac = continuous_step_fraction(n_cuts, alpha)
        rows.append((f"lemma1/discrete_n{n_cuts}_a{alpha}", 1.0,
                     f"reduction={1-frac:.4f}"))
    see = build_plan(kind="seesaw", base_lr=1.0, total_tokens=2 ** 30,
                     warmup_frac=0.1, b0=256, alpha=1.1, n_cuts=40)
    ref = build_plan(kind="cosine", base_lr=1.0, total_tokens=2 ** 30,
                     warmup_frac=0.1, b0=256, alpha=1.1, n_cuts=40)
    us = (time.time() - t0) * 1e6
    sp = measured_speedup(see, ref, 1024)
    rows.append(("lemma1/plan_measured_speedup", us, f"{sp:.4f}"))
    rows.append(("lemma1/limit_2_over_pi", 0.1, f"{2/math.pi:.4f}"))
    return rows
