"""Engine dispatch benchmark: eager per-step host round-trips vs K-step
fused dispatch (K ∈ {1, 4, 16}).

"eager" reproduces the pre-engine trainer loop per step: the LR curve
evaluated op-by-op on host, one jitted dispatch per batch, and a
blocking ``float(v)`` transfer for every metric.  The fused rows run
the engine path: LR on device, K batches per dispatch from the
double-buffered chunk loader, metrics transferred once per chunk.

Two regimes, both through the identical ``PhaseEngine.run_chunk`` code
path:

- ``dispatch`` — a reduced-scale LM (the bench_figure1 idiom: same code
  path as the 150M preset, tiny dims) where the per-step executable is
  a few ms, so host overhead is the dominant term fusion removes.
  This is where the K=16 ≥ 1.5× steps/sec win shows.
- ``smoke150m`` — ``SEESAW_150M.reduced()``, whose ~1.4M-param step is
  compute-bound on a 2-core CPU host (≈19 ms/step executable); the
  fused win shrinks toward the compute floor, which is the point: the
  overhead fusion removes is a constant per step, not a fraction.

Timed step counts are multiples of every K so the timed region is
steady-state (the merged, tail-padded chunk stream compiles one
program per distinct batch size regardless).  Each run also reports
its compile/executable count — the artifact carries a ``compiles``
section measuring the "one executable per distinct batch size" claim
on multi-phase ramps (seesaw: one per ramp stage; 'step': a single
merged-segment program even though the plan has several phases).

    PYTHONPATH=src python -m benchmarks.bench_engine \
        [--steps 144] [--out artifacts/bench_engine.json]

Emits one JSON artifact (like the dry-run benches) plus the harness's
``name,us_per_call,derived`` CSV rows via ``run()``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.configs.seesaw_paper import SEESAW_150M
from repro.data import MarkovLM, PhaseDataLoader
from repro.train.trainer import Trainer

# reduced-scale LM: dispatch-overhead-bound on CPU (a few ms per step)
DISPATCH_LM = ModelConfig(name="engine-lm", arch_type="dense",
                          n_layers=2, d_model=128, n_heads=4,
                          n_kv_heads=4, head_dim=32, d_ff=256,
                          vocab_size=512, max_seq_len=64,
                          rope_theta=1e4)
KS = (1, 4, 16)


def _cfg(model: ModelConfig, seq: int, b0: int, steps: int,
         backend: str = None) -> RunConfig:
    # cosine: single phase (constant chunk shape) AND the legacy loop's
    # op-by-op host LR evaluation is real work in the eager baseline
    return RunConfig(
        model=model,
        schedule=ScheduleConfig(kind="cosine", base_lr=1e-3),
        optimizer=OptimizerConfig(kind="adamw"),
        seq_len=seq, global_batch_size=b0,
        total_tokens=seq * b0 * steps, remat=False,
        kernel_backend=backend)


def _bench_eager(model, seq, b0, steps, backend=None) -> float:
    """The legacy loop: host LR + per-step blocking metric transfers."""
    tr = Trainer(_cfg(model, seq, b0, steps + 1, backend), fuse_steps=1)
    loader = PhaseDataLoader(MarkovLM(512, seed=0), tr.plan, seq,
                             prefetch=0)
    it = iter(loader)
    _, _, batch = next(it)                     # warmup: compile
    st = tr.state
    p, o, m = tr.engine.run_chunk(st.params, st.opt_state, 0.0,
                                  jax.tree.map(lambda x: x[None], batch))
    jax.device_get(m)
    t0 = time.perf_counter()
    n, tokens = 0, float(seq * b0)
    for _, _, batch in it:
        jnp.asarray(tr.lr_at(tokens), jnp.float32)        # host LR
        p, o, m = tr.engine.run_chunk(
            p, o, tokens, jax.tree.map(lambda x: x[None], batch))
        _ = {k: float(v[0]) for k, v in m.items()}        # blocking
        tokens += seq * b0
        n += 1
    return n / (time.perf_counter() - t0)


def _bench_fused(model, seq, b0, steps, k, backend=None):
    tr = Trainer(_cfg(model, seq, b0, steps + k, backend), fuse_steps=k)
    loader = PhaseDataLoader(MarkovLM(512, seed=0), tr.plan, seq)
    chunks = loader.iter_chunks(k)
    _, stacked, m0 = next(chunks)              # warmup: compile
    st = tr.state
    p, o, m = tr.engine.run_chunk(st.params, st.opt_state, 0,
                                  stacked, n_valid=m0)
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    n, tokens, pending = 0, m0 * seq * b0, []
    for _, stacked, mk in chunks:
        p, o, m = tr.engine.run_chunk(p, o, tokens, stacked,
                                      n_valid=mk, step=n)
        pending.append(m)                      # deferred transfer
        tokens += mk * seq * b0
        n += mk
    jax.block_until_ready(p)
    jax.device_get(pending)
    return n / (time.perf_counter() - t0), len(tr.engine._cache)


def _regime(name, model, seq, b0, steps, rows, result, backend=None):
    sps_eager = _bench_eager(model, seq, b0, steps, backend)
    rows.append((f"engine/{name}/eager_per_step_sync", 1e6 / sps_eager,
                 f"steps_per_s={sps_eager:.1f}"))
    reg = {"model": model.name, "seq_len": seq, "batch_size": b0,
           "steps": steps, "eager_steps_per_s": round(sps_eager, 2),
           "fused": {}}
    for k in KS:
        sps, n_exec = _bench_fused(model, seq, b0, steps, k, backend)
        rows.append((f"engine/{name}/fused_k{k}", 1e6 / sps,
                     f"steps_per_s={sps:.1f} "
                     f"speedup_vs_eager={sps / sps_eager:.2f}x "
                     f"executables={n_exec}"))
        reg["fused"][str(k)] = {
            "steps_per_s": round(sps, 2),
            "speedup_vs_eager": round(sps / sps_eager, 3),
            "executables": n_exec}
    sps16 = reg["fused"]["16"]["steps_per_s"]
    reg["host_overhead_ms_per_step"] = round(
        1e3 * (1.0 / sps_eager - 1.0 / sps16), 2)
    rows.append((f"engine/{name}/host_overhead_us_per_step",
                 1e6 * (1.0 / sps_eager - 1.0 / sps16),
                 "eager_minus_fused16"))
    result[name] = reg


def _compile_counts(rows, result, backend=None):
    """Measure the 'one executable per distinct batch size' claim on
    multi-phase ramps at K=16 with step counts that are NOT multiples
    of 16 (tail padding in play).  seesaw ramps through 3 batch sizes
    → 3 programs; 'step' (β=1) has 3 phases but one batch size → its
    merged chunk stream compiles a single program."""
    out = {}
    for kind in ("seesaw", "step"):
        cfg = RunConfig(
            model=DISPATCH_LM,
            schedule=ScheduleConfig(kind=kind, base_lr=1e-3, alpha=2.0,
                                    n_cuts=2),
            optimizer=OptimizerConfig(kind="adamw"),
            seq_len=16, global_batch_size=2,
            total_tokens=16 * 2 * 52, remat=False,
            kernel_backend=backend)
        tr = Trainer(cfg, fuse_steps=16)
        tr.run(PhaseDataLoader(MarkovLM(512, seed=0), tr.plan, 16))
        out[kind] = {
            "phases": len(tr.plan.phases),
            "distinct_batch_sizes": len(set(tr.plan.batch_sizes())),
            "executables": len(tr.engine._cache),
            "chunk_ks": sorted({key[2] for key in tr.engine._cache}),
            "steps": len(tr.history)}
        rows.append((f"engine/compiles/{kind}",
                     float(out[kind]["executables"]),
                     f"distinct_b={out[kind]['distinct_batch_sizes']} "
                     f"steps={out[kind]['steps']} k16_only="
                     f"{out[kind]['chunk_ks'] == [16]}"))
    result["compiles"] = out


def _adaptive_compiles(rows, result, backend=None):
    """The compile-count story under *dynamically created* phases: an
    adaptive-seesaw run whose plateau controller fires at runtime must
    still compile one K-sized executable per distinct batch size —
    runtime LR tables mean a cut changes argument values, never
    programs — plus at most one background pre-warm in flight (counted
    before the joined thread's program is first dispatched)."""
    cfg = RunConfig(
        model=DISPATCH_LM,
        schedule=ScheduleConfig(kind="adaptive-seesaw", base_lr=1e-2,
                                warmup_frac=0.02, alpha=2.0, n_cuts=4,
                                plateau_window=16,
                                plateau_threshold=2e-2, ema_decay=0.9),
        optimizer=OptimizerConfig(kind="adamw"),
        seq_len=16, global_batch_size=2,
        total_tokens=16 * 2 * 360, remat=False,
        kernel_backend=backend)
    tr = Trainer(cfg, fuse_steps=16)
    tr.run(PhaseDataLoader(MarkovLM(512, seed=0), tr.plan, 16))
    rec = {
        "phases": len(tr.plan.phases),
        "cuts": len(tr.cut_tokens),
        "distinct_batch_sizes": len(set(tr.plan.batch_sizes())),
        "executables": len(tr.engine._cache),
        "prewarms_in_flight": len(tr.engine._prewarm),
        "chunk_ks": sorted({key[2] for key in tr.engine._cache}),
        "steps": len(tr.history)}
    rows.append(("engine/compiles/adaptive",
                 float(rec["executables"]),
                 f"cuts={rec['cuts']} "
                 f"distinct_b={rec['distinct_batch_sizes']} "
                 f"steps={rec['steps']} k16_only="
                 f"{rec['chunk_ks'] == [16]}"))
    result["compiles"]["adaptive"] = rec


def _measure(steps: int = 144, backend: str = None,
             compiles_only: bool = False, schedule: str = None):
    steps -= steps % 48          # keep divisible by every K in KS
    steps = max(steps, 48)
    rows, result = [], {}
    result["backend"] = backend or "xla"
    if not compiles_only:
        _regime("dispatch", DISPATCH_LM, 16, 1, steps, rows, result,
                backend)
        _regime("smoke150m", SEESAW_150M.reduced(), 16, 1,
                min(steps, 48), rows, result, backend)
    _compile_counts(rows, result, backend)
    if schedule == "adaptive-seesaw":
        _adaptive_compiles(rows, result, backend)
    return rows, result


def run(steps: int = 144):
    """Harness entry point (``python -m benchmarks.run --only engine``):
    CSV rows only."""
    rows, _ = _measure(steps)
    return rows


def check_compiles(result) -> list:
    """The PR 4 invariant, as a CI gate (``--check-compiles``): every
    multi-phase ramp in the ``compiles`` section must have compiled
    exactly one K-sized fused executable per *distinct* batch size —
    a regression here means remainder programs are back."""
    errors = []
    for kind, rec in result["compiles"].items():
        if kind == "adaptive":
            # dynamic phases: one program per distinct batch size plus
            # at most one background pre-warm still in flight
            if rec["executables"] > rec["distinct_batch_sizes"] + 1:
                errors.append(
                    f"adaptive: {rec['executables']} executables for "
                    f"{rec['distinct_batch_sizes']} distinct batch "
                    f"sizes (+1 in-flight pre-warm allowed)")
            if rec["cuts"] < 1:
                errors.append(
                    "adaptive: the plateau controller never fired — "
                    "the smoke did not exercise dynamic phases")
        elif rec["executables"] != rec["distinct_batch_sizes"]:
            errors.append(
                f"{kind}: {rec['executables']} executables for "
                f"{rec['distinct_batch_sizes']} distinct batch sizes")
        if rec["chunk_ks"] != [16]:
            errors.append(
                f"{kind}: chunk programs {rec['chunk_ks']} != [16] — "
                f"a tail chunk compiled its own remainder program")
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=144)
    ap.add_argument("--out", default="artifacts/bench_engine.json")
    ap.add_argument("--backend", default=None,
                    choices=["xla", "pallas", "pallas_interpret"],
                    help="kernel backend axis (see "
                         "repro.kernels.backend); default xla")
    ap.add_argument("--compiles-only", action="store_true",
                    help="skip the timing regimes, run only the "
                         "compile-count section (the fast CI gate for "
                         "non-default backends)")
    ap.add_argument("--check-compiles", action="store_true",
                    help="exit non-zero unless the compiles section "
                         "shows one fused executable per distinct "
                         "batch size (the CI bench-smoke gate)")
    ap.add_argument("--schedule", default=None,
                    choices=["adaptive-seesaw"],
                    help="add a schedule-specific compiles section: "
                         "adaptive-seesaw runs the plateau controller "
                         "live and asserts dynamic phases stay within "
                         "one executable per distinct batch size "
                         "(+1 in-flight pre-warm)")
    args = ap.parse_args()
    rows, result = _measure(args.steps, backend=args.backend,
                            compiles_only=args.compiles_only,
                            schedule=args.schedule)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"→ {args.out}")
    if args.check_compiles:
        errors = check_compiles(result)
        for e in errors:
            print(f"compiles invariant VIOLATED: {e}")
        if errors:
            raise SystemExit(1)
        print("compiles invariant OK: one executable per distinct "
              "batch size")


if __name__ == "__main__":
    main()
