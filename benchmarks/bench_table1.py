"""Table 1: final losses, cosine vs Seesaw, across batch sizes — the
exact NSGD recursions sweep B ∈ {8,16,32,64} (CBS-relative), and the
reduced-scale LM confirms one point end-to-end."""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core import theory as T


def run():
    rows = []
    lam = T.power_law_spectrum(100, a=1.0)
    eta = T.stability_eta(lam)
    sigma2 = 1.0
    for B in (8, 16, 32, 64):
        t0 = time.time()
        m0 = T.warm_start(lam, sigma2, eta, B, 2000)
        eta_n = eta * math.sqrt(sigma2 * np.sum(lam) / B)
        samples = [B * 512] * 5
        ph_step = T.phase_schedule(eta_n, B, 2.0, 1.0, samples)
        ph_see = T.phase_schedule(eta_n, B, math.sqrt(2.0), 2.0, samples)
        r1, _, _ = T.run_schedule(lam, sigma2, ph_step, m0=m0,
                                  normalized=True,
                                  assume_variance_dominated=True)
        r2, _, _ = T.run_schedule(lam, sigma2, ph_see, m0=m0,
                                  normalized=True,
                                  assume_variance_dominated=True)
        us = (time.time() - t0) * 1e6
        rows.append((f"table1/B{B}_risk_cosine", us, f"{r1[-1]:.3e}"))
        rows.append((f"table1/B{B}_risk_seesaw", us, f"{r2[-1]:.3e}"))
        rows.append((f"table1/B{B}_ratio", us,
                     f"{float(r2[-1]/r1[-1]):.4f}"))
    return rows
