"""Analytic per-device FLOPs / HBM-bytes model for every (arch × shape).

Needed because XLA's ``compiled.cost_analysis()`` on the CPU backend
counts a ``lax.scan`` body ONCE instead of ×trip-count, so its 'flops'
underestimates scanned models by ~n_layers.  These closed-form counts
are exact for the matmul-dominated terms (the ≥99% of FLOPs that
matter) and are cross-checked against cost_analysis via
flops_model ≈ cost_flops_body × n_layers in tests.

Conventions: one fused multiply-add = 2 FLOPs; training = 3× forward
(backward 2×) + 1× forward again when remat is on ⇒ 4× forward;
causal-masked attention is charged FULL S² for the baseline XLA path
(it computes masked blocks) and S²/2 with block_skip (§Perf lever).
"""
from __future__ import annotations

from dataclasses import dataclass
from repro.configs.base import (HybridConfig, InputShape,
                                ModelConfig, SSMConfig)
from repro.configs.base import _pattern as pattern_of


def _attn_layer_flops(cfg: ModelConfig, S_q: int, S_kv: int,
                      causal_half: bool = False) -> float:
    d = cfg.d_model
    proj = 2 * S_q * d * (cfg.q_dim + 2 * cfg.kv_dim) \
        + 2 * S_q * cfg.q_dim * d
    sc = 2 * S_q * S_kv * cfg.q_dim * 2          # QK^T and PV
    if causal_half:
        sc /= 2
    return proj + sc


def _mlp_flops(cfg: ModelConfig, S: int) -> float:
    n_mats = 3 if cfg.act == "silu" else 2
    return 2 * S * cfg.d_model * cfg.d_ff * n_mats


def _moe_layer_flops(cfg: ModelConfig, S: int) -> float:
    m = cfg.moe
    assert m is not None
    router = 2 * S * cfg.d_model * m.num_experts
    # capacity dispatch computes cf·k expert slots per token
    slots = S * m.top_k * m.capacity_factor
    expert = 2 * slots * cfg.d_model * m.d_expert * 3
    return router + expert


def _ssd_layer_flops(cfg: ModelConfig, S: int) -> float:
    s = cfg.ssm or SSMConfig()
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.n_ssm_heads(d)
    N, Pd, Q = s.d_state, s.head_dim, s.chunk_size
    proj = 2 * S * d * (2 * di + 2 * N + H) + 2 * S * di * d
    Qe = min(Q, S)
    # intra-chunk: CB (Q²N) + M@x (Q²P per head) ; states (QNP per head)
    intra = 2 * S * Qe * N + 2 * S * Qe * Pd * H
    states = 2 * S * N * Pd * H * 2              # build + apply
    return proj + intra + states


def _rglru_layer_flops(cfg: ModelConfig, S: int) -> float:
    h = cfg.hybrid or HybridConfig()
    w = h.lru_width or cfg.d_model
    d = cfg.d_model
    proj = 2 * S * d * w * 2 + 2 * S * w * d      # gate, x, out
    gates = 2 * S * w * w * 2                     # W_r, W_i
    return proj + gates


def _vocab_flops(cfg: ModelConfig, S: int) -> float:
    return 2 * S * cfg.d_model * cfg.padded_vocab


def forward_flops(cfg: ModelConfig, batch: int, seq: int, *,
                  mode: str = "train", block_skip: bool = False) -> float:
    """Total forward FLOPs (all devices) for one step of the workload."""
    S = batch * seq                                # total tokens
    L = cfg.n_layers
    half = block_skip
    if cfg.arch_type == "ssm":
        core = L * _ssd_layer_flops(cfg, S)
    elif cfg.arch_type == "hybrid":
        h = cfg.hybrid or HybridConfig()
        kinds = pattern_of(cfg, L)
        core = 0.0
        for kind in kinds:
            if kind == "recurrent":
                core += _rglru_layer_flops(cfg, S)
            else:
                skv = min(seq, h.local_window) if mode != "decode" else seq
                core += _attn_layer_flops(cfg, S, skv * 0 + min(
                    seq, h.local_window), causal_half=half)
            core += _mlp_flops(cfg, S)
    elif cfg.arch_type in ("encdec", "audio"):
        Se = batch * cfg.frontend_tokens
        St = S
        enc = cfg.n_encoder_layers * (
            _attn_layer_flops(cfg, Se, cfg.frontend_tokens)
            + _mlp_flops(cfg, Se))
        dec = L * (_attn_layer_flops(cfg, St, seq, causal_half=half)
                   + _attn_layer_flops(cfg, St, cfg.frontend_tokens)
                   + _mlp_flops(cfg, St))
        core = enc + dec
    elif cfg.arch_type == "moe":
        core = L * (_attn_layer_flops(cfg, S, seq, causal_half=half)
                    + _moe_layer_flops(cfg, S))
    else:
        skv = min(seq, cfg.sliding_window or seq)
        core = L * (_attn_layer_flops(cfg, S, skv, causal_half=half)
                    + _mlp_flops(cfg, S))
    return core + _vocab_flops(cfg, S if mode == "train" else batch)


def step_flops(cfg: ModelConfig, shape: InputShape, *,
               remat: bool = True, block_skip: bool = False) -> float:
    if shape.mode == "train":
        text = shape.seq_len
        f = forward_flops(cfg, shape.global_batch, text, mode="train",
                          block_skip=block_skip)
        return f * (4.0 if remat else 3.0)
    if shape.mode == "prefill":
        return forward_flops(cfg, shape.global_batch, shape.seq_len,
                             mode="prefill", block_skip=block_skip)
    # decode: one token against a seq_len cache/state
    if cfg.arch_type == "ssm":
        s = cfg.ssm or SSMConfig()
        d = cfg.d_model
        di = s.d_inner(d)
        H = s.n_ssm_heads(d)
        per_tok = cfg.n_layers * (
            2 * d * (2 * di + 2 * s.d_state + H) + 2 * di * d
            + 2 * H * s.head_dim * s.d_state * 2)
        return (per_tok + 2 * d * cfg.padded_vocab) * shape.global_batch
    kv = shape.seq_len
    if cfg.sliding_window:
        kv = min(kv, cfg.sliding_window)
    if cfg.arch_type == "hybrid":
        h = cfg.hybrid or HybridConfig()
        kinds = pattern_of(cfg, cfg.n_layers)
        w = h.lru_width or cfg.d_model
        per_tok = 0.0
        for kind in kinds:
            if kind == "recurrent":
                per_tok += 2 * cfg.d_model * w * 3 + 2 * w * w * 2
            else:
                per_tok += _attn_layer_flops(cfg, 1, min(shape.seq_len,
                                                         h.local_window))
            per_tok += _mlp_flops(cfg, 1)
        return (per_tok + 2 * cfg.d_model * cfg.padded_vocab) \
            * shape.global_batch
    per_tok = cfg.n_layers * (_attn_layer_flops(cfg, 1, kv)
                              + (_moe_layer_flops(cfg, 1)
                                 if cfg.arch_type == "moe"
                                 else _mlp_flops(cfg, 1)))
    if cfg.arch_type in ("encdec", "audio"):
        per_tok += cfg.n_layers * _attn_layer_flops(cfg, 1,
                                                    cfg.frontend_tokens)
    return (per_tok + 2 * cfg.d_model * cfg.padded_vocab) \
        * shape.global_batch


def model_flops_per_token(cfg: ModelConfig) -> float:
    """The 6·N(active)·D convention (per token, training)."""
    return 6.0 * cfg.active_param_count()


def hbm_bytes(cfg: ModelConfig, shape: InputShape, *, chips: int,
              remat: bool = True) -> float:
    """Per-device HBM traffic estimate for one step.

    Training: params+grads+opt-state read/write (f32 master, sharded
    over all chips) + bf16 weight all-gather destinations + saved
    activations write/read + O(10) residual-stream passes per layer.
    Serving: params read + cache read/write.
    """
    N = cfg.param_count()
    if shape.mode == "train":
        # f32 master params/opt: p rw, m rw, v rw, grads w — all sharded
        opt_traffic = N * 4 * 7 / chips
        # bf16 weights are all-gathered per layer: each device WRITES and
        # then READS a full bf16 copy per pass (fwd, bwd, +remat fwd)
        weight_traffic = N * 2 * 2 * (3 if remat else 2)
        tokens_local = shape.global_batch * shape.seq_len / chips
        act = tokens_local * cfg.d_model * 2              # one bf16 pass
        L = max(cfg.n_layers, 1)
        # ~10 residual-stream-sized reads/writes per layer per pass,
        # ×(fwd + bwd + remat-fwd)
        act_traffic = L * act * 10 * (3 if remat else 2)
        return opt_traffic + weight_traffic + act_traffic
    if shape.mode == "prefill":
        tokens_local = shape.global_batch * shape.seq_len / chips
        act = tokens_local * cfg.d_model * 2
        L = max(cfg.n_layers, 1)
        return N * 2 * 2 + L * act * 10 + _cache_bytes(cfg, shape) / chips
    # decode: read the model once per token + touch the cache
    return N * 2 * 2 + _cache_bytes(cfg, shape) / chips * 2


def _cache_bytes(cfg: ModelConfig, shape: InputShape) -> float:
    B, S = shape.global_batch, shape.seq_len
    if cfg.arch_type == "ssm":
        s = cfg.ssm or SSMConfig()
        H = s.n_ssm_heads(cfg.d_model)
        return cfg.n_layers * B * (H * s.head_dim * s.d_state * 4
                                   + (s.d_conv - 1)
                                   * (s.d_inner(cfg.d_model)
                                      + 2 * s.d_state) * 2)
    if cfg.arch_type == "hybrid":
        h = cfg.hybrid or HybridConfig()
        w = h.lru_width or cfg.d_model
        kinds = pattern_of(cfg, cfg.n_layers)
        tot = 0.0
        for kind in kinds:
            if kind == "recurrent":
                tot += B * (w * 4 + (h.conv1d_width - 1) * w * 2)
            else:
                tot += B * min(S, h.local_window) * cfg.kv_dim * 2 * 2
        return tot
    W = min(S, cfg.sliding_window or S)
    return cfg.n_layers * B * W * cfg.kv_dim * 2 * 2
