"""Figure 2 / Table 2: the equivalence line α√β = 2 — points with
α ≥ √β match the (2,1) baseline; the aggressive end (α<√β) destabilizes
(Lemma 4).  Exact NSGD recursions."""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core import theory as T
from repro.core.seesaw import divergence_risk

# Table 2 of the paper: alpha in {2, 2^(3/4), 2^(1/2), 2^(1/4), 1},
# beta chosen so alpha*sqrt(beta) = 2
POINTS = [(2.0, 1.0), (2 ** 0.75, 2 ** 0.5), (2 ** 0.5, 2.0),
          (2 ** 0.25, 2 ** 1.5), (1.0, 4.0)]


def run():
    rows = []
    lam = T.power_law_spectrum(100, a=1.0)
    eta = T.stability_eta(lam)
    sigma2, B = 1.0, 8
    m0 = T.warm_start(lam, sigma2, eta, B, 2000)
    # a larger base LR exposes the instability of the infeasible points
    eta_n = 30 * eta * math.sqrt(sigma2 * np.sum(lam) / B)
    samples = [B * 1024] * 10
    base = None
    for alpha, beta in POINTS:
        t0 = time.time()
        ph = T.phase_schedule(eta_n, B, alpha, beta, samples)
        r, _, _ = T.run_schedule(lam, sigma2, ph, m0=m0, normalized=True,
                                 assume_variance_dominated=True)
        us = (time.time() - t0) * 1e6
        final = r[-1]
        if base is None:
            base = final
        ratio = final / base if np.isfinite(final) else float("inf")
        feasible = not divergence_risk(alpha, beta)
        tagged = "feasible" if feasible else "INFEASIBLE(Lemma4)"
        rows.append((f"figure2/a{alpha:.3f}_b{beta:.3f}", us,
                     f"ratio={ratio:.3f} {tagged}"))
    return rows
