"""Figure 1: Seesaw matches cosine in loss-vs-tokens while cutting
serial steps — reduced-scale LM run through the real trainer (the same
code path as the 150M preset) + the exact theory sim at paper-like depth.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.core import theory as T
from repro.data import MarkovLM, PhaseDataLoader
from repro.train.trainer import Trainer

MODEL = ModelConfig(name="fig1-lm", arch_type="dense", n_layers=2,
                    d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                    d_ff=256, vocab_size=512, max_seq_len=64,
                    rope_theta=1e4)


def _train(kind: str, steps: int = 150):
    cfg = RunConfig(model=MODEL,
                    schedule=ScheduleConfig(kind=kind, base_lr=3e-3,
                                            alpha=2.0, n_cuts=4),
                    optimizer=OptimizerConfig(kind="adamw"),
                    seq_len=64, global_batch_size=8,
                    total_tokens=64 * 8 * steps, remat=False)
    tr = Trainer(cfg)
    hist = tr.run(PhaseDataLoader(MarkovLM(512, seed=0), tr.plan, 64))
    return hist


def run():
    rows = []
    t0 = time.time()
    h_cos = _train("cosine")
    h_see = _train("seesaw")
    wall = (time.time() - t0) * 1e6 / (len(h_cos) + len(h_see))
    lc = float(np.mean([h["loss"] for h in h_cos[-5:]]))
    ls = float(np.mean([h["loss"] for h in h_see[-5:]]))
    red = 1 - len(h_see) / len(h_cos)
    rows.append(("figure1/lm_cosine_final_loss", wall, f"{lc:.4f}"))
    rows.append(("figure1/lm_seesaw_final_loss", wall, f"{ls:.4f}"))
    rows.append(("figure1/lm_loss_gap", wall, f"{abs(lc-ls):.4f}"))
    rows.append(("figure1/lm_step_reduction", wall, f"{red:.3f}"))

    # theory sim at paper-like cut depth (α=1.1 ⇒ many cuts)
    lam = T.power_law_spectrum(100, a=1.0)
    eta = T.stability_eta(lam)
    m0 = T.warm_start(lam, 1.0, eta, 8, 2000)
    t0 = time.time()
    import math
    eta_n = eta * math.sqrt(np.sum(lam) / 8)
    # cosine-approximating step decay (α=2 cuts) vs Seesaw (√2, ×2)
    ph_step = T.phase_schedule(eta_n, 8, 2.0, 1.0, [8192] * 5)
    ph_see = T.phase_schedule(eta_n, 8, math.sqrt(2.0), 2.0, [8192] * 5)
    r1, _, _ = T.run_schedule(lam, 1.0, ph_step, m0=m0, normalized=True,
                              assume_variance_dominated=True)
    r2, _, _ = T.run_schedule(lam, 1.0, ph_see, m0=m0, normalized=True,
                              assume_variance_dominated=True)
    us = (time.time() - t0) * 1e6
    steps_ref = sum(p.steps for p in ph_step)
    steps_see = sum(p.steps for p in ph_see)
    rows.append(("figure1/theory_risk_ratio", us,
                 f"{float(r2[-1]/r1[-1]):.4f}"))
    rows.append(("figure1/theory_step_reduction", us,
                 f"{1 - steps_see/steps_ref:.3f}"))
    return rows
