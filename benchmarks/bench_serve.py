"""Serving benchmark: continuous batching vs. static batching, plus a
Poisson load sweep through the paged engine.

A deterministic load generator (seeded; arrivals are Poisson in the
engine-step domain, so the trace is identical across hosts regardless
of wall-clock speed) submits requests with ragged prompt lengths and
bimodal generation budgets — mostly short replies with a long tail —
the workload shape where static batching hurts: a batch blocks on its
longest member while finished rows idle.

Reported:

- a ``throughput`` section comparing the continuous-batching
  ``ServingEngine`` against the static-batch ``Server`` baseline
  (requests grouped in arrival order, prompts padded to a shared
  length, every batch generating its own max budget — the old blocking
  API's contract) on the SAME mixed-length workload.  ``speedup`` is
  engine requests/s over static requests/s; the acceptance floor is
  1.5x.
- per arrival rate: ``requests_per_s`` / ``tokens_per_s`` drain
  throughput, ``latency_ms`` p50/p99/mean submit-to-finish wall time
  (queueing included: at high rate the p99 grows while p50 holds, the
  continuous-batching signature), and ``mean_occupancy`` decode-slot
  utilisation.
- a ``compiles`` section measuring the serving compile invariant:
  prefill executables <= #prompt-buckets and EXACTLY ONE decode
  executable, which ``--check-compiles`` turns into a CI gate (the
  serving counterpart of bench_engine's one-executable-per-batch-size
  gate).

Warmup touches every prompt bucket once and runs a decode step, then
``reset()`` keeps the compile cache and frees the pool, so the timed
region measures steady-state serving, not compilation; the static
baseline is warmed the same way (one untimed pass).

    PYTHONPATH=src python -m benchmarks.bench_serve \
        [--requests 48] [--ci] [--check-compiles] \
        [--check-speedup 1.5] [--out artifacts/bench_serve.json]

Emits one JSON artifact plus the harness's ``name,us_per_call,derived``
CSV rows via ``run()``.
"""
from __future__ import annotations

import argparse
import json
import os
import time
import warnings

import jax
import numpy as np

from repro.configs import ModelConfig
from repro.models import registry as R
from repro.serving import GenerationRequest, ServingEngine
from repro.train.serve import Server

# reduced-scale LM, the bench_engine idiom: same serving code path as
# the real presets, tiny dims so CPU CI finishes in minutes
SERVE_LM = ModelConfig(name="serve-lm", arch_type="dense", n_layers=2,
                       d_model=128, n_heads=4, n_kv_heads=2,
                       head_dim=32, d_ff=256, vocab_size=512,
                       max_seq_len=256, rope_theta=1e4)

DECODE_SLOTS = 4
PAGE_SIZE = 16
MAX_LEN = 128
RATES = (0.5, 2.0)          # mean arrivals per engine step


def _make_engine(params):
    return ServingEngine(SERVE_LM, params, decode_slots=DECODE_SLOTS,
                         page_size=PAGE_SIZE, max_len=MAX_LEN)


def _request(rng) -> GenerationRequest:
    """One mixed-workload request: ~3/4 short replies (4..10 tokens),
    ~1/4 long generations (40..64) — the bimodal shape that makes a
    static batch block on its slowest member."""
    if rng.random() < 0.75:
        max_new = int(rng.integers(4, 11))
    else:
        max_new = int(rng.integers(40, 65))
    s = int(rng.integers(2, MAX_LEN - max_new))
    prompt = rng.integers(0, SERVE_LM.vocab_size, (s,)).astype(np.int32)
    return GenerationRequest(prompt=prompt, max_new_tokens=max_new)


def _trace(n_requests: int, rate: float, seed: int):
    """Deterministic Poisson trace: (arrival_step, request) sorted by
    arrival."""
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        out.append((t, _request(rng)))
    return out


def _warmup(engine: ServingEngine):
    """Compile every prompt-bucket prefill and the decode executable,
    then drop the requests but keep the compile cache."""
    rng = np.random.default_rng(1)
    for i, b in enumerate(engine.buckets):
        s = b if i == 0 else engine.buckets[i - 1] + 1
        if s + 2 > engine.max_len:
            s = engine.max_len - 2
        engine.submit(GenerationRequest(
            max_new_tokens=2,
            prompt=rng.integers(0, SERVE_LM.vocab_size, (s,)).astype(
                np.int32)))
    engine.drain(max_steps=200)
    engine.reset()


def _drive(engine: ServingEngine, trace) -> dict:
    """Submit the trace against engine-step time and drain; returns the
    per-rate metrics block."""
    t_submit, t_finish, n_tokens = {}, {}, {}
    step, q = 0, 0
    t0 = time.perf_counter()
    while q < len(trace) or not engine.done:
        while q < len(trace) and trace[q][0] <= step:
            rid = engine.submit(trace[q][1])
            t_submit[rid] = time.perf_counter()
            q += 1
        for rid, _tok, fin in engine.step():
            n_tokens[rid] = n_tokens.get(rid, 0) + 1
            if fin:
                t_finish[rid] = time.perf_counter()
        step += 1
        assert step < 100_000, "engine failed to drain the trace"
    elapsed = time.perf_counter() - t0
    lat = np.asarray([1e3 * (t_finish[r] - t_submit[r])
                      for r in t_finish])
    total_tokens = sum(n_tokens.values())
    return {
        "n_requests": len(trace),
        "steps": step,
        "requests_per_s": round(len(trace) / elapsed, 2),
        "tokens_per_s": round(total_tokens / elapsed, 1),
        "generated_tokens": total_tokens,
        "latency_ms": {
            "p50": round(float(np.percentile(lat, 50)), 1),
            "p99": round(float(np.percentile(lat, 99)), 1),
            "mean": round(float(lat.mean()), 1)},
        "mean_occupancy": round(engine.mean_occupancy(), 3),
    }


def _static_baseline(params, requests, *, timed: bool) -> dict:
    """The old blocking API on the same workload: requests grouped in
    arrival order into batches of DECODE_SLOTS, prompts padded to the
    batch max, each batch generating its own worst-case budget — every
    request waits for its batch's slowest member."""
    srv = Server(SERVE_LM, params, max_len=MAX_LEN)
    t0 = time.perf_counter()
    useful = 0
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for i in range(0, len(requests), DECODE_SLOTS):
            batch = requests[i:i + DECODE_SLOTS]
            s_max = max(len(r.prompt) for r in batch)
            toks = np.zeros((len(batch), s_max), np.int32)
            for j, r in enumerate(batch):
                # right-align so the last column is each prompt's final
                # token (the static API's shared-length contract)
                toks[j, s_max - len(r.prompt):] = r.prompt
            n_new = max(r.max_new_tokens for r in batch)
            out = srv.generate(toks, n_new)
            useful += sum(min(n_new, r.max_new_tokens) for r in batch)
            del out
    elapsed = time.perf_counter() - t0
    if not timed:
        return {}
    return {
        "n_requests": len(requests),
        "requests_per_s": round(len(requests) / elapsed, 2),
        "useful_tokens_per_s": round(useful / elapsed, 1),
        "batch_size": DECODE_SLOTS,
    }


def _measure(n_requests: int = 48, seed: int = 0):
    rows, result = [], {}
    params = R.init_params(jax.random.PRNGKey(0), SERVE_LM)
    engine = _make_engine(params)
    _warmup(engine)
    result.update({
        "model": SERVE_LM.name,
        "decode_slots": DECODE_SLOTS,
        "page_size": PAGE_SIZE,
        "max_len": MAX_LEN,
        "buckets": list(engine.buckets),
        "pool_pages": engine.pool.capacity,
        "rates": {},
    })

    # throughput comparison on one backlog workload (everything queued
    # up front): continuous batching vs. the static-batch Server
    backlog = [r for _, r in _trace(n_requests, 1e9, seed)]
    engine.reset()
    eng_rec = _drive(engine, [(0.0, r) for r in backlog])
    _static_baseline(params, backlog, timed=False)      # warm compile
    sta_rec = _static_baseline(params, backlog, timed=True)
    speedup = round(eng_rec["requests_per_s"]
                    / max(sta_rec["requests_per_s"], 1e-9), 2)
    result["throughput"] = {
        "engine": eng_rec, "static": sta_rec, "speedup": speedup}
    rows.append(("serve/throughput/speedup", float(speedup),
                 f"engine_rps={eng_rec['requests_per_s']} "
                 f"static_rps={sta_rec['requests_per_s']} floor=1.5"))

    for rate in RATES:
        engine.reset()
        rec = _drive(engine, _trace(n_requests, rate, seed))
        result["rates"][str(rate)] = rec
        rows.append((
            f"serve/rate{rate}/request",
            1e6 / max(rec["requests_per_s"], 1e-9),
            f"req_per_s={rec['requests_per_s']} "
            f"tok_per_s={rec['tokens_per_s']} "
            f"p50_ms={rec['latency_ms']['p50']} "
            f"p99_ms={rec['latency_ms']['p99']} "
            f"occupancy={rec['mean_occupancy']}"))
    result["compiles"] = {
        "prefill_executables": engine.n_prefill_executables,
        "decode_executables": engine.n_decode_executables,
        "executables": engine.executables,
        "prompt_buckets": len(engine.buckets),
        "decode_batch_sizes": 1,
        "budget": engine.executable_budget,
    }
    rows.append(("serve/compiles", float(engine.executables),
                 f"budget={engine.executable_budget} "
                 f"buckets={len(engine.buckets)} decode_batches=1"))
    return rows, result


def run(steps: int = 144):
    """Harness entry point (``python -m benchmarks.run --only serve``):
    CSV rows only."""
    rows, _ = _measure(n_requests=16)
    return rows


def check_compiles(result) -> list:
    """The serving compile invariant as a CI gate: after serving ragged
    prompts across every bucket at two arrival rates plus the backlog
    workload, the engine must hold at most one prefill executable per
    prompt bucket and exactly one decode executable."""
    errors = []
    c = result["compiles"]
    if c["executables"] > c["budget"]:
        errors.append(
            f"{c['executables']} executables exceed the budget "
            f"{c['budget']} (= {c['prompt_buckets']} prompt buckets "
            f"+ {c['decode_batch_sizes']} decode batch sizes)")
    if c["prefill_executables"] > c["prompt_buckets"]:
        errors.append(
            f"{c['prefill_executables']} prefill executables for "
            f"{c['prompt_buckets']} prompt buckets — per-prompt-length "
            f"recompiles are back")
    if c["decode_executables"] != c["decode_batch_sizes"]:
        errors.append(
            f"{c['decode_executables']} decode executables for "
            f"{c['decode_batch_sizes']} decode batch sizes")
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48,
                    help="requests per workload (throughput comparison "
                         "and each arrival-rate sweep point)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ci", action="store_true",
                    help="reduced request count for the CI smoke")
    ap.add_argument("--check-compiles", action="store_true",
                    help="exit non-zero unless prefill executables <= "
                         "#prompt-buckets and decode executables == 1")
    ap.add_argument("--check-speedup", type=float, default=None,
                    help="exit non-zero unless engine/static requests/s "
                         ">= this floor (wall-clock: not a CI gate)")
    ap.add_argument("--out", default="artifacts/bench_serve.json")
    args = ap.parse_args()
    n = 16 if args.ci else args.requests
    rows, result = _measure(n_requests=n, seed=args.seed)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"→ {args.out}")
    ok = True
    if args.check_compiles:
        errors = check_compiles(result)
        for e in errors:
            print(f"serving compile invariant VIOLATED: {e}")
        ok = ok and not errors
        if not errors:
            print("serving compile invariant OK: one decode executable, "
                  "prefill executables <= #prompt-buckets")
    if args.check_speedup is not None:
        sp = result["throughput"]["speedup"]
        if sp < args.check_speedup:
            print(f"continuous-batching speedup {sp}x below the "
                  f"{args.check_speedup}x floor")
            ok = False
        else:
            print(f"continuous-batching speedup OK: {sp}x >= "
                  f"{args.check_speedup}x")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
