"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only figure1]

Prints ``name,us_per_call,derived`` CSV.  The roofline table (§g) is a
separate artifact: ``python -m benchmarks.roofline``.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_engine, bench_figure1, bench_figure2,
                            bench_figure3, bench_figure4_wd,
                            bench_figure5, bench_figure6_zloss,
                            bench_lemma1, bench_serve, bench_table1)
    suites = {
        "figure1": bench_figure1,
        "table1": bench_table1,
        "figure2": bench_figure2,
        "figure3": bench_figure3,
        "figure4": bench_figure4_wd,
        "figure5": bench_figure5,
        "figure6": bench_figure6_zloss,
        "lemma1": bench_lemma1,
        "engine": bench_engine,
        "serve": bench_serve,
    }
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in suites.items():
        if args.only and args.only != name:
            continue
        try:
            for row in mod.run():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}")
        except Exception as e:           # pragma: no cover
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
        sys.stdout.flush()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
