"""Appendix E (Figures 6–7): z-loss ablation — final loss is unchanged
with z-loss on/off under cosine, and the z² statistic is tracked under
Seesaw (the paper observed end-of-training z-loss instabilities with
Seesaw at 600M; we surface the statistic so the effect is measurable)."""
from __future__ import annotations

import time

import numpy as np

from repro.configs import (ModelConfig, OptimizerConfig, RunConfig,
                           ScheduleConfig)
from repro.data import MarkovLM, PhaseDataLoader
from repro.train.trainer import Trainer

MODEL = ModelConfig(name="fig6-lm", arch_type="dense", n_layers=2,
                    d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                    d_ff=256, vocab_size=512, max_seq_len=64,
                    rope_theta=1e4)


def _train(kind: str, z: float, steps: int = 100):
    cfg = RunConfig(model=MODEL,
                    schedule=ScheduleConfig(kind=kind, base_lr=3e-3,
                                            alpha=2.0, n_cuts=3),
                    optimizer=OptimizerConfig(kind="adamw"),
                    seq_len=64, global_batch_size=8, z_loss=z,
                    total_tokens=64 * 8 * steps, remat=False)
    tr = Trainer(cfg)
    return tr.run(PhaseDataLoader(MarkovLM(512, seed=0), tr.plan, 64))


def run():
    rows = []
    t0 = time.time()
    h_off = _train("cosine", 0.0)
    h_on = _train("cosine", 1e-4)
    h_see = _train("seesaw", 1e-4)
    us = (time.time() - t0) * 1e6 / (len(h_off) + len(h_on) + len(h_see))
    lo = float(np.mean([h["ce_loss"] for h in h_off[-5:]]))
    ln = float(np.mean([h["ce_loss"] for h in h_on[-5:]]))
    rows.append(("figure6/zloss_off_ce", us, f"{lo:.4f}"))
    rows.append(("figure6/zloss_on_ce", us, f"{ln:.4f}"))
    rows.append(("figure6/zloss_neutral", us, str(abs(lo - ln) < 0.12)))
    z_end = float(np.mean([h["z_sq"] for h in h_see[-5:]]))
    z_mid = float(np.mean([h["z_sq"]
                           for h in h_see[len(h_see)//2 - 2:
                                          len(h_see)//2 + 3]]))
    rows.append(("figure7/seesaw_z_sq_mid", us, f"{z_mid:.3f}"))
    rows.append(("figure7/seesaw_z_sq_end", us, f"{z_end:.3f}"))
    return rows
