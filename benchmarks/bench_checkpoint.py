"""Checkpoint stall benchmark: synchronous save wall time vs the
step-loop stall of an async ``CheckpointManager.request_save``.

A synchronous save blocks the training loop for the full
device→host-stream→fsync→commit round-trip.  The async path only
blocks for the on-device snapshot (a jitted ``jnp.copy`` of the state
tree, donation-safe) plus the thread handoff — the streaming and the
manifest commit happen on the writer thread while the next fused
chunks dispatch.  This bench measures both on the 150M smoke config
(``SEESAW_150M.reduced()``, the same workload bench_engine times) and
reports the ratio, which is the factor by which periodic
checkpointing stops taxing step time.

    PYTHONPATH=src python -m benchmarks.bench_checkpoint \
        [--saves 5] [--out artifacts/bench_checkpoint.json] \
        [--check-stall] [--check-schema]

``--check-stall`` gates the ratio (async stall at least 5x smaller);
``--check-schema`` instead round-trips one checkpoint and validates
the on-disk manifest schema (format version, generation, meta fields,
per-shard file/bounds/crc32/writer) plus crc integrity — the cheap CI
artifact proving the format contract without timing noise.  Emits one
JSON artifact plus the harness's ``name,us_per_call,derived`` CSV
rows via ``run()``.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import statistics
import tempfile
import time

import jax
import numpy as np

from repro.configs import (OptimizerConfig, RunConfig, ScheduleConfig)
from repro.configs.seesaw_paper import SEESAW_150M
from repro.data import MarkovLM, PhaseDataLoader
from repro.train import checkpoint as CKPT
from repro.train.trainer import Trainer

SEQ = 64
B0 = 2
STEPS = 8


def _trainer() -> Trainer:
    model = SEESAW_150M.reduced()
    cfg = RunConfig(
        model=model,
        schedule=ScheduleConfig(kind="cosine", base_lr=1e-3),
        optimizer=OptimizerConfig(kind="adamw"),
        seq_len=SEQ, global_batch_size=B0,
        total_tokens=SEQ * B0 * STEPS, remat=False)
    tr = Trainer(cfg, fuse_steps=4)
    # a few real steps so the timed saves write converged-shape state
    # (opt state populated, tokens_seen mid-run), not init noise
    tr.run(PhaseDataLoader(MarkovLM(min(model.vocab_size, 2048),
                                    seed=0), tr.plan, SEQ))
    return tr


def _bench_stalls(tr: Trainer, workdir: str, saves: int):
    st = tr.state
    sync_s, async_s = [], []
    sync_dir = os.path.join(workdir, "sync")
    for i in range(saves):
        shutil.rmtree(sync_dir, ignore_errors=True)
        t0 = time.perf_counter()
        CKPT.save_phase_checkpoint(sync_dir, st.params, st.opt_state,
                                   st.step, st.tokens_seen,
                                   plan=tr.plan,
                                   seq_len=tr.cfg.seq_len)
        sync_s.append(time.perf_counter() - t0)

    mgr = tr.engine.make_checkpoint_manager()
    async_dir = os.path.join(workdir, "async")
    for i in range(saves):
        t0 = time.perf_counter()
        mgr.request_save(async_dir, st.params, st.opt_state,
                         st.step + i, st.tokens_seen)
        async_s.append(time.perf_counter() - t0)
        mgr.wait()               # not timed: drain before next request
    mgr.finalize()
    assert mgr.saves_committed >= 1
    return statistics.median(sync_s), statistics.median(async_s)


def _measure(saves: int = 5):
    tr = _trainer()
    workdir = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        stall_sync, stall_async = _bench_stalls(tr, workdir, saves)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    ratio = stall_sync / max(stall_async, 1e-9)
    n_bytes = sum(x.nbytes for x in
                  jax.tree.leaves(tr.state.params)
                  + jax.tree.leaves(tr.state.opt_state))
    result = {"model": tr.cfg.model.name, "state_bytes": n_bytes,
              "saves": saves,
              "stall_sync_s": round(stall_sync, 4),
              "stall_async_s": round(stall_async, 4),
              "ratio": round(ratio, 2)}
    rows = [("checkpoint/stall_sync", 1e6 * stall_sync,
             f"state_mb={n_bytes / 1e6:.1f}"),
            ("checkpoint/stall_async", 1e6 * stall_async,
             f"ratio_vs_sync={ratio:.1f}x")]
    return rows, result


def run(saves: int = 5):
    """Harness entry point (``python -m benchmarks.run``): CSV rows."""
    rows, _ = _measure(saves)
    return rows


def check_stall(result) -> list:
    """CI gate: the async request must stall the step loop at least
    5x less than a blocking save of the same state."""
    if result["ratio"] < 5.0:
        return [f"async stall ratio {result['ratio']}x < 5x "
                f"(sync {result['stall_sync_s']}s, "
                f"async {result['stall_async_s']}s)"]
    return []


def check_schema() -> list:
    """Round-trip one checkpoint of the smoke state and validate the
    on-disk contract: manifest format/generation, meta fields the
    resume path depends on, per-shard file/bounds/crc32/writer entries,
    crc integrity of every block, and a bitwise restore."""
    errors = []
    tr = _trainer()
    workdir = tempfile.mkdtemp(prefix="bench_ckpt_schema_")
    base = os.path.join(workdir, "ck")
    st = tr.state
    try:
        CKPT.save_phase_checkpoint(base, st.params, st.opt_state,
                                   st.step, st.tokens_seen,
                                   plan=tr.plan,
                                   seq_len=tr.cfg.seq_len)
        with open(os.path.join(base, "manifest.json")) as f:
            man = json.load(f)
        if man.get("format") != CKPT.FORMAT_VERSION:
            errors.append(f"format {man.get('format')} != "
                          f"{CKPT.FORMAT_VERSION}")
        if man.get("generation") != 0:
            errors.append(f"first generation {man.get('generation')}")
        meta = man.get("meta", {})
        for key in ("step", "tokens_seen", "phase", "batch_size",
                    "save_process_count"):
            if key not in meta:
                errors.append(f"meta missing {key!r}")
        n_leaves = len(jax.tree.leaves(st.params)) \
            + len(jax.tree.leaves(st.opt_state))
        if len(man.get("arrays", {})) != n_leaves:
            errors.append(f"{len(man.get('arrays', {}))} manifest "
                          f"leaves != {n_leaves} state leaves")
        for name, entry in man.get("arrays", {}).items():
            for field in ("shape", "dtype", "shards"):
                if field not in entry:
                    errors.append(f"{name}: missing {field!r}")
            for shard in entry.get("shards", []):
                for field in ("file", "start", "stop", "crc32",
                              "writer"):
                    if field not in shard:
                        errors.append(f"{name}: shard missing "
                                      f"{field!r}")
                path = os.path.join(base, shard.get("file", ""))
                if not os.path.isfile(path):
                    errors.append(f"{name}: {shard.get('file')} "
                                  f"missing on disk")
                elif CKPT._crc_of_file(path) != shard.get("crc32"):
                    errors.append(f"{name}: crc mismatch on "
                                  f"{shard.get('file')}")
        p2, o2, meta2 = CKPT.restore(base, st.params, st.opt_state,
                                     verify=True)
        for a, b in zip(jax.tree.leaves(st.params),
                        jax.tree.leaves(p2)):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                errors.append("restored params not bitwise")
                break
        if CKPT.exact_tokens(meta2["tokens_seen"]) != st.tokens_seen:
            errors.append("tokens_seen did not round-trip")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--saves", type=int, default=5)
    ap.add_argument("--out", default=None)
    ap.add_argument("--check-stall", action="store_true")
    ap.add_argument("--check-schema", action="store_true")
    args = ap.parse_args()

    if args.check_schema:
        errors = check_schema()
        result = {"schema_ok": not errors, "errors": errors}
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".",
                        exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(result, f, indent=2, sort_keys=True)
            print(f"wrote {args.out}")
        if errors:
            raise SystemExit("schema check failed:\n  "
                             + "\n  ".join(errors))
        print("schema check passed")
        return

    rows, result = _measure(args.saves)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
        print(f"wrote {args.out}")
    if args.check_stall:
        errors = check_stall(result)
        if errors:
            raise SystemExit("stall check failed:\n  "
                             + "\n  ".join(errors))
        print(f"stall check passed: {result['ratio']}x")


if __name__ == "__main__":
    main()
