"""Figure 5: scheduler comparison at CBS — fixed-LR batch doubling
(blue), fixed-LR quadrupling (orange), α=2 step decay (green), Seesaw
(red).  Exact NSGD recursions; the naive ramps underperform."""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core import theory as T

SCHEDULES = [
    ("naive_double", 1.0, 2.0),
    ("naive_quadruple", 1.0, 4.0),     # infeasible per Lemma 4
    ("step_decay", 2.0, 1.0),
    ("seesaw", math.sqrt(2.0), 2.0),
]


def run():
    rows = []
    lam = T.power_law_spectrum(100, a=1.0)
    eta = T.stability_eta(lam)
    sigma2, B = 1.0, 8
    m0 = T.warm_start(lam, sigma2, eta, B, 2000)
    # a well-tuned (near-edge-of-stability) base LR, as at CBS in the
    # paper: the naive ramps' non-decaying effective LR then leaves a
    # higher noise floor (blue/orange in Fig. 5), and the β=4 ramp
    # destabilizes outright (Lemma 4)
    eta_n = 40 * eta * math.sqrt(sigma2 * np.sum(lam) / B)
    samples = [B * 1024] * 8
    results = {}
    for name, a, b in SCHEDULES:
        t0 = time.time()
        ph = T.phase_schedule(eta_n, B, a, b, samples)
        r, _, _ = T.run_schedule(lam, sigma2, ph, m0=m0, normalized=True,
                                 assume_variance_dominated=False)
        us = (time.time() - t0) * 1e6
        results[name] = float(r[-1])
        rows.append((f"figure5/{name}_final_risk", us, f"{r[-1]:.3e}"))
    ok = (results["seesaw"] <= results["naive_double"] * 1.05 and
          results["step_decay"] <= results["naive_double"] * 1.05)
    rows.append(("figure5/naive_underperforms", 0.0, str(ok)))
    return rows
