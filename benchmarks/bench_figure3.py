"""Figure 3 / §4.2: past the critical batch size Assumption 2 fails —
neither Seesaw nor the SGD-rule ramp matches LR decay.  We run the NSGD
recursion with the EXACT E‖g‖² denominator (mean + variance), so the
mean term's batch-independence emerges naturally as B grows."""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core import theory as T


def run():
    rows = []
    lam = T.power_law_spectrum(100, a=1.0)
    eta = T.stability_eta(lam)
    sigma2 = 0.05          # small noise ⇒ variance stops dominating early
    for B in (8, 256, 2048):
        t0 = time.time()
        m0 = T.warm_start(lam, sigma2, eta, 8, 1000)
        eta_n = 20 * eta * math.sqrt(np.sum(lam) * sigma2 / B)
        samples = [B * 256] * 6
        kw = dict(normalized=True, assume_variance_dominated=False)
        # LR decay baseline (α=2, β=1)
        r_dec, _, _ = T.run_schedule(
            lam, sigma2, T.phase_schedule(eta_n, B, 2.0, 1.0, samples),
            m0=m0, **kw)
        # Seesaw ramp (√2, ×2)
        r_see, _, _ = T.run_schedule(
            lam, sigma2,
            T.phase_schedule(eta_n, B, math.sqrt(2.0), 2.0, samples),
            m0=m0, **kw)
        us = (time.time() - t0) * 1e6
        gap_see = float(r_see[-1] / r_dec[-1])
        rows.append((f"figure3/B{B}_seesaw_over_decay", us,
                     f"{gap_see:.3f}"))

    # §4.2 NGD toy: L(x)=½hx² — without LR decay NGD converges to a
    # stable cycle of amplitude ηh; any batch ramp leaves it unchanged,
    # only LR decay escapes it.
    t0 = time.time()
    h_q, eta_q, x = 1.0, 0.1, 1.03
    for _ in range(200):
        x = x - eta_q * h_q * np.sign(x)
    cycle_amp = abs(x)
    x2, e2 = 1.03, eta_q
    for t in range(200):
        if t % 25 == 24:
            e2 /= 2.0
        x2 = x2 - e2 * h_q * np.sign(x2)
    us = (time.time() - t0) * 1e6
    rows.append(("figure3/ngd_cycle_no_decay", us, f"{cycle_amp:.4f}"))
    rows.append(("figure3/ngd_with_lr_decay", us, f"{abs(x2):.6f}"))
    rows.append(("figure3/ngd_decay_required", us,
                 str(abs(x2) < cycle_amp / 10)))
    return rows
