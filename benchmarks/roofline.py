"""Roofline analysis (deliverable g): derive the three terms per
(arch × shape × mesh) from the dry-run artifacts + the analytic model.

  compute    = FLOPs / (chips × 197 TFLOP/s)
  memory     = HBM bytes / (chips × 819 GB/s)
  collective = collective bytes / (chips × 50 GB/s/link)

FLOPs/HBM come from benchmarks.flops_model (closed-form; XLA's CPU
cost_analysis undercounts scan bodies — recorded alongside for
cross-checking).  Collective bytes come from the optimized-HLO parse:
top-level bytes + loop-body bytes × layer-scan trip count.

    PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import HybridConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

from benchmarks import flops_model as FM


def _trip_count(cfg) -> int:
    """Trip count of the dominant (layer) scan."""
    if cfg.arch_type == "hybrid":
        pat = (cfg.hybrid or HybridConfig()).pattern
        return max(cfg.n_layers // len(pat), 1)
    return max(cfg.n_layers, 1)


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    block_skip = rec.get("tag", "baseline") != "baseline" and \
        "skip" in rec.get("tag", "")

    flops_total = FM.step_flops(cfg, shape, block_skip=block_skip)
    t_compute = flops_total / (chips * PEAK_FLOPS_BF16)

    hbm = FM.hbm_bytes(cfg, shape, chips=chips)
    t_memory = hbm / HBM_BW

    cb = rec.get("collective_bytes", {})
    cl = rec.get("collective_bytes_in_loop", {})
    if "error" in cb:
        coll = 0.0
    else:
        trips = _trip_count(cfg)
        coll = sum(cb.values()) + trips * sum(cl.values())
    t_coll = coll / ICI_BW            # bytes already per-device (SPMD HLO)

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = FM.model_flops_per_token(cfg)
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.mode != "decode" else 1)
    model_flops = mf * tokens * (1.0 if shape.mode == "train" else 1 / 3)
    useful = model_flops / flops_total if flops_total else 0.0

    temp = rec.get("memory_analysis", {}).get("temp_size_in_bytes")
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", "baseline"),
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "step_flops": flops_total,
        "model_flops": model_flops,
        "useful_frac": useful,
        "collective_bytes_dev": coll,
        "hbm_bytes_dev": hbm,
        "temp_bytes_dev": temp,
        "cost_analysis_flops": rec.get("cost_analysis", {}).get("flops"),
        "compile_s": rec.get("compile_s"),
    }


def load_all(dir_: str, tag: str = "baseline") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("tag", "baseline") != tag:
            continue
        row = analyze_record(rec)
        if row:
            out.append(row)
        elif rec.get("status") == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "dominant": "skipped",
                        "reason": rec.get("reason", "")})
    return out


def fmt_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s "
           "| dominant | useful | temp GB |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r["dominant"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | skipped | — | — |")
            continue
        temp = r.get("temp_bytes_dev")
        temp_s = f"{temp/1e9:.1f}" if temp else "?"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} | "
            f"{r['t_collective_s']:.2e} | **{r['dominant']}** | "
            f"{r['useful_frac']:.2f} | {temp_s} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = load_all(args.dir, args.tag)
    print(fmt_table(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
    # headline: worst pairs per selection criteria (single-pod only)
    ok = [r for r in rows if r["dominant"] != "skipped"
          and r["mesh"] == "16x16"]
    if ok:
        worst_useful = min(ok, key=lambda r: r["useful_frac"])
        most_coll = max(ok, key=lambda r: (r["t_collective_s"]
                                           / max(max(r["t_compute_s"],
                                                     r["t_memory_s"]),
                                                 1e-12)))
        print(f"\nworst useful-FLOP fraction: {worst_useful['arch']} × "
              f"{worst_useful['shape']} ({worst_useful['useful_frac']:.2f})")
        print(f"most collective-bound: {most_coll['arch']} × "
              f"{most_coll['shape']} "
              f"(coll/max(comp,mem) = "
              f"{most_coll['t_collective_s']/max(max(most_coll['t_compute_s'],most_coll['t_memory_s']),1e-12):.2f})")


if __name__ == "__main__":
    main()
