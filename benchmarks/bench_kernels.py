"""Per-kernel roofline sweeps + a DMA/compute-overlap profile.

Two sections, one JSON artifact (``artifacts/bench_kernels.json``):

1. **Kernel sweeps** — forward and backward (``jax.grad``) wall-clock
   for each hot-path op (flash attention, RMSNorm, SSD) across shape /
   block-size configs, on the selected backend AND the XLA reference
   path, with analytic FLOP / byte counts so each row places itself on
   a roofline (``flops_per_byte`` = arithmetic intensity; compare
   ``achieved_gflops`` against the machine's compute and HBM ceilings).
   On this CPU container the Pallas numbers run under
   ``interpret=True`` — they validate the sweep machinery and the
   *relative* block-size trends, not absolute TPU throughput; re-run
   with ``--backend pallas`` on real hardware for roofline placement.

2. **Overlap profile** — a streaming normalize kernel (HBM-resident
   operands, ``memory_space=ANY``) that pipelines row-blocks through
   VMEM with explicit ``make_async_copy`` in/out queues, swept over
   (block_rows × buffer_depth) in the style of quad-buffering
   benchmarks.  Buffer depth 1 serializes DMA against compute; depth
   ≥ 2 overlaps them — the depth where the curve flattens is the
   latency-hiding knee.  Configs whose in+out VMEM footprint
   ``2 · depth · block · d · 4B`` exceeds the VMEM budget are recorded
   as skipped, not run (the same guard a production kernel needs).

    PYTHONPATH=src python -m benchmarks.bench_kernels \
        [--backend pallas_interpret] [--ci] \
        [--out artifacts/bench_kernels.json]

``--ci`` shrinks every sweep to smoke-size so the job finishes in
seconds on 2 CPU cores (uploaded as ``bench_kernels.ci.json``).
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend as KB
from repro.kernels import ref

VMEM_BUDGET = 16 * 1024 * 1024          # bytes/core, v4/v5-class


# --------------------------------------------------------------------- #
# timing
# --------------------------------------------------------------------- #

def _time_ms(fn, *args, warmup: int = 1, reps: int = 3) -> float:
    """Median wall-clock of a jitted callable, compile excluded."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e3)
    return sorted(ts)[len(ts) // 2]


def _grad_of(fn, n_in: int):
    """sum-of-outputs scalarization → grad wrt the first n_in args."""
    def loss(*a):
        out = fn(*a)
        leaves = jax.tree.leaves(out)
        return sum(jnp.sum(x.astype(jnp.float32)) for x in leaves)
    return jax.grad(loss, argnums=tuple(range(n_in)))


# --------------------------------------------------------------------- #
# kernel sweeps
# --------------------------------------------------------------------- #

def _attention_sweep(backend: str, ci: bool):
    cfgs = ([(1, 4, 2, 128, 64, 64)] if ci else
            [(1, 4, 2, 256, 64, 64), (1, 4, 2, 256, 64, 128),
             (1, 8, 2, 512, 64, 128), (2, 4, 4, 256, 64, 64)])
    rows = []
    for (B, H, Hkv, S, hd, blk) in cfgs:
        key = jax.random.PRNGKey(0)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
        k = jax.random.normal(kk, (B, S, Hkv, hd), jnp.float32)
        v = jax.random.normal(kv, (B, S, Hkv, hd), jnp.float32)

        def attn(q, k, v, be):
            return KB.attention(q, k, v, causal=True, backend=be,
                                block_q=blk, block_k=blk)

        row = {"B": B, "H": H, "Hkv": Hkv, "S": S, "head_dim": hd,
               "block": blk}
        # causal: ~half the S² pairs; 2 matmuls (qk, pv), fwd+bwd ≈ 3.5×
        flops = 2 * 2 * B * H * S * S * hd / 2
        bytes_moved = 4 * (B * S * hd * (H + 2 * Hkv) * 2)
        row["gflops_fwd"] = round(flops / 1e9, 3)
        row["flops_per_byte"] = round(flops / bytes_moved, 1)
        for be, tag in ((backend, "kernel"), ("xla", "xla")):
            f = jax.jit(functools.partial(attn, be=be))
            g = jax.jit(_grad_of(functools.partial(attn, be=be), 3))
            fwd = _time_ms(f, q, k, v)
            bwd = _time_ms(g, q, k, v)
            row[f"{tag}_fwd_ms"] = round(fwd, 3)
            row[f"{tag}_bwd_ms"] = round(bwd, 3)
            row[f"{tag}_achieved_gflops"] = round(flops / fwd / 1e6, 2)
        rows.append(row)
    return rows


def _rmsnorm_sweep(backend: str, ci: bool):
    cfgs = ([(1024, 256, 256)] if ci else
            [(4096, 512, 128), (4096, 512, 256), (4096, 512, 512),
             (16384, 1024, 256)])
    rows = []
    from repro.kernels import rmsnorm as RN
    for (n, d, br) in cfgs:
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (n, d), jnp.float32)
        s = 0.1 * jax.random.normal(key, (d,), jnp.float32)
        interp = backend == "pallas_interpret"

        def kern(x, s):
            if backend == "xla":
                return ref.rmsnorm_ref(x, s)
            return RN.rmsnorm(x, s, block_rows=br, interpret=interp)

        row = {"rows": n, "d": d, "block_rows": br}
        # memory-bound: 1 read + 1 write of (n, d) f32
        gb = 2 * n * d * 4 / 1e9
        for fn, tag in ((kern, "kernel"),
                        (lambda x, s: ref.rmsnorm_ref(x, s), "xla")):
            f = jax.jit(fn)
            g = jax.jit(_grad_of(fn, 2))
            fwd = _time_ms(f, x, s)
            bwd = _time_ms(g, x, s)
            row[f"{tag}_fwd_ms"] = round(fwd, 3)
            row[f"{tag}_bwd_ms"] = round(bwd, 3)
            row[f"{tag}_gb_per_s"] = round(gb / (fwd / 1e3), 2)
        rows.append(row)
    return rows


def _ssd_sweep(backend: str, ci: bool):
    cfgs = ([(1, 128, 2, 32, 16, 32)] if ci else
            [(1, 256, 4, 64, 32, 64), (1, 256, 4, 64, 32, 128),
             (2, 512, 4, 64, 32, 128)])
    rows = []
    for (B, S, H, P, N, chunk) in cfgs:
        ks = jax.random.split(jax.random.PRNGKey(2), 6)
        xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.abs(jax.random.normal(ks[2], (H,))) * 0.5
        Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
        Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
        D = jax.random.normal(ks[5], (H,)) * 0.1

        def kern(*a, be):
            return KB.ssd(*a, chunk=chunk, backend=be)

        row = {"B": B, "S": S, "H": H, "P": P, "N": N, "chunk": chunk}
        # intra-chunk quadratic dominates: 2·(CBᵀ) + 2·(M@x) per chunk
        nc = S // chunk
        flops = B * H * nc * (2 * chunk * chunk * N
                              + 2 * chunk * chunk * P)
        row["gflops_fwd"] = round(flops / 1e9, 3)
        for be, tag in ((backend, "kernel"), ("xla", "xla")):
            f = jax.jit(functools.partial(kern, be=be))
            g = jax.jit(_grad_of(functools.partial(kern, be=be), 6))
            fwd = _time_ms(f, xh, dt, A, Bm, Cm, D)
            bwd = _time_ms(g, xh, dt, A, Bm, Cm, D)
            row[f"{tag}_fwd_ms"] = round(fwd, 3)
            row[f"{tag}_bwd_ms"] = round(bwd, 3)
        rows.append(row)
    return rows


# --------------------------------------------------------------------- #
# DMA/compute-overlap profile
# --------------------------------------------------------------------- #

def _overlap_kernel(x_ref, o_ref, in_bufs, out_bufs, in_sems, out_sems,
                    *, block: int, n_blocks: int, depth: int,
                    eps: float = 1e-5):
    """Streaming normalize with a depth-deep DMA pipeline.

    x/o live in ANY (HBM); row-block i flows HBM →(in-DMA)→
    in_bufs[i % depth] →(compute)→ out_bufs[i % depth] →(out-DMA)→ HBM.
    In-DMA for block i+depth is issued as soon as slot (i % depth)
    frees; the out-DMA wait for block i−depth gates reuse of the out
    slot.  depth=1 fully serializes; the overlap win is the measured
    gap between depth 1 and the knee.
    """

    def in_dma(i):
        slot = jax.lax.rem(i, depth)
        return pltpu.make_async_copy(
            x_ref.at[pl.ds(i * block, block)],
            in_bufs.at[slot],
            in_sems.at[slot])

    def out_dma(i):
        slot = jax.lax.rem(i, depth)
        return pltpu.make_async_copy(
            out_bufs.at[slot],
            o_ref.at[pl.ds(i * block, block)],
            out_sems.at[slot])

    # prologue: fill the pipeline
    for j in range(min(depth, n_blocks)):
        in_dma(jnp.int32(j)).start()

    def body(i, _):
        slot = jax.lax.rem(i, depth)
        in_dma(i).wait()
        # out slot must have drained before we overwrite it
        @pl.when(i >= depth)
        def _drain():
            out_dma(i - depth).wait()
        x = in_bufs[slot].astype(jnp.float32)
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        out_bufs[slot] = x * jax.lax.rsqrt(var + eps)
        out_dma(i).start()
        # refill the in slot we just consumed
        @pl.when(i + depth < n_blocks)
        def _refill():
            in_dma(i + depth).start()
        return 0

    jax.lax.fori_loop(0, n_blocks, body, 0)
    # epilogue: drain the last `depth` out-copies
    start = jnp.maximum(n_blocks - depth, 0)

    def drain(i, _):
        out_dma(i).wait()
        return 0

    jax.lax.fori_loop(start, n_blocks, drain, 0)


def _overlap_call(x, *, block: int, depth: int, interpret: bool):
    rows, d = x.shape
    n_blocks = rows // block
    kernel = functools.partial(_overlap_kernel, block=block,
                               n_blocks=n_blocks, depth=depth)
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((depth, block, d), jnp.float32),   # in bufs
            pltpu.VMEM((depth, block, d), jnp.float32),   # out bufs
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
        interpret=interpret,
    )(x)


def _overlap_profile(backend: str, ci: bool):
    """(block_rows × buffer_depth) sweep, VMEM-limit aware."""
    if backend == "xla":
        return {"skipped": "overlap profile needs a pallas backend"}
    interpret = backend == "pallas_interpret"
    rows_total, d = (2048, 256) if ci else (8192, 512)
    blocks = [128, 256] if ci else [128, 256, 512, 1024]
    depths = [1, 2] if ci else [1, 2, 4, 8]
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (rows_total, d), jnp.float32)
    want = ref.rmsnorm_ref(x, jnp.zeros((d,)))   # scale=0 ⇒ pure norm
    out = {"rows": rows_total, "d": d, "vmem_budget_bytes": VMEM_BUDGET,
           "configs": []}
    for block in blocks:
        if rows_total % block:
            continue
        for depth in depths:
            vmem = 2 * depth * block * d * 4
            rec = {"block_rows": block, "buffer_depth": depth,
                   "vmem_bytes": vmem}
            if vmem > VMEM_BUDGET:
                rec["skipped"] = "exceeds VMEM budget"
                out["configs"].append(rec)
                continue
            fn = jax.jit(functools.partial(
                _overlap_call, block=block, depth=depth,
                interpret=interpret))
            got = fn(x)
            rec["max_err"] = float(jnp.abs(got - want).max())
            rec["ms"] = round(_time_ms(fn, x), 3)
            gb = 2 * rows_total * d * 4 / 1e9
            rec["gb_per_s"] = round(gb / (rec["ms"] / 1e3), 2)
            out["configs"].append(rec)
    ran = [c for c in out["configs"] if "ms" in c]
    if ran:
        best = min(ran, key=lambda c: c["ms"])
        out["best"] = {k: best[k] for k in
                       ("block_rows", "buffer_depth", "ms", "gb_per_s")}
    return out


# --------------------------------------------------------------------- #
# entry points
# --------------------------------------------------------------------- #

def _measure(backend: str, ci: bool):
    result = {"backend": backend,
              "platform": jax.devices()[0].platform,
              "interpret": backend == "pallas_interpret"}
    result["attention"] = _attention_sweep(backend, ci)
    result["rmsnorm"] = _rmsnorm_sweep(backend, ci)
    result["ssd"] = _ssd_sweep(backend, ci)
    result["overlap"] = _overlap_profile(backend, ci)
    return result


def run(steps: int = 0):
    """Harness entry point: CSV rows from a CI-sized sweep."""
    result = _measure("pallas_interpret", ci=True)
    rows = []
    for section in ("attention", "rmsnorm", "ssd"):
        for r in result[section]:
            name = f"kernels/{section}/" + "x".join(
                str(v) for k, v in r.items() if isinstance(v, int))
            rows.append((name, r["kernel_fwd_ms"] * 1e3,
                         f"bwd_ms={r['kernel_bwd_ms']}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="pallas_interpret",
                    choices=["xla", "pallas", "pallas_interpret"],
                    help="backend for the kernel columns (the xla "
                         "columns always run for comparison)")
    ap.add_argument("--ci", action="store_true",
                    help="smoke-size sweeps (seconds on 2 CPU cores)")
    ap.add_argument("--out", default="artifacts/bench_kernels.json")
    args = ap.parse_args()
    result = _measure(args.backend, args.ci)
    for section in ("attention", "rmsnorm", "ssd"):
        for r in result[section]:
            print(f"{section}: {r}")
    ov = result["overlap"]
    for c in ov.get("configs", []):
        print(f"overlap: {c}")
    if "best" in ov:
        print(f"overlap best: {ov['best']}")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"→ {args.out}")


if __name__ == "__main__":
    main()
